//! Fault-injection suite for the campaign supervision layer (ISSUE 7
//! acceptance): transient faults recover via retry with bytes identical
//! to a fault-free run, persistent faults quarantine without
//! contaminating neighbors, cancellation drains to bitwise-resumable
//! state, and corrupt cache entries are recomputed (and counted).
//!
//! Everything here is deterministic: fault rules key off frozen spec
//! strings with explicit fire counts, cancellation uses the poll-counted
//! [`CancelToken::after_checks`] trigger, and backoff is disabled so no
//! decision depends on wall time.

use std::fs;
use std::path::PathBuf;

use repro::coordinator::{
    run_plan, run_plan_supervised, Backoff, CampaignOpts, CancelToken, FaultPlan, OnFault,
    PointResult, RunSpec, SweepPlan, SweepPoint,
};
use repro::pdes::{Mode, StreamFamily, Topology, VolumeLoad};

/// A small 4-point plan whose specs are mutually non-overlapping on the
/// `l=<L>;` substring, so a fault rule can target exactly one point.
fn plan() -> SweepPlan {
    let mut plan = SweepPlan::new("faultprobe", "supervision test plan");
    for l in [10usize, 12, 14, 16] {
        plan.push(SweepPoint::steady(
            format!("L{l}"),
            Topology::Ring { l },
            RunSpec {
                l,
                load: VolumeLoad::Sites(1),
                mode: Mode::Conservative,
                trials: 2,
                steps: 0,
                seed: 7,
                streams: StreamFamily::Pe,
                control: repro::coordinator::Control::Static,
            },
            40,
            40,
        ));
    }
    plan
}

/// Canonical byte identity of a result set: the cache-text encoding
/// carries raw f64 bit patterns, so equal strings = bitwise-equal data.
fn texts(results: &[PointResult]) -> Vec<String> {
    results.iter().map(|r| r.to_cache_text()).collect()
}

fn tmp_dir(tag: &str) -> PathBuf {
    let dir = std::env::temp_dir().join(format!("repro_faultinj_{tag}"));
    fs::remove_dir_all(&dir).ok();
    dir
}

/// Fault-free reference results for [`plan`].
fn reference() -> Vec<String> {
    let (results, report) = run_plan(&plan(), &CampaignOpts::default()).unwrap();
    assert_eq!(report.executed, 4);
    texts(&results)
}

#[test]
fn transient_panic_recovers_via_retry_bitwise() {
    let reference = reference();
    // the first 2 executions of the l=12 point panic; retries cover it
    let opts = CampaignOpts {
        workers: 2,
        max_retries: 3,
        backoff: Backoff::none(),
        faults: Some(FaultPlan::new().panic_on("l=12;", 2)),
        quiet: true,
        ..Default::default()
    };
    let (results, report) = run_plan(&plan(), &opts).unwrap();
    assert_eq!(report.retried, 2, "both injected panics consumed a retry");
    assert!(report.quarantined.is_empty());
    assert!(!report.cancelled);
    assert_eq!(report.executed, 4);
    assert_eq!(
        texts(&results),
        reference,
        "recovered campaign must be byte-identical to a fault-free run"
    );
}

#[test]
fn persistent_fault_quarantines_without_contamination() {
    let reference = reference();
    let dir = tmp_dir("quarantine");
    let manifest = dir.join("FAILED.manifest");
    let opts = CampaignOpts {
        workers: 2,
        max_retries: 1,
        backoff: Backoff::none(),
        faults: Some(FaultPlan::new().panic_on("l=12;", u32::MAX)),
        cache_dir: Some(dir.join(".cache")),
        failed_manifest: Some(manifest.clone()),
        quiet: true,
        ..Default::default()
    };

    // the strict wrapper surfaces the quarantine as a typed error
    let err = run_plan(&plan(), &opts).unwrap_err().to_string();
    assert!(err.contains("quarantined"), "unexpected error: {err}");
    assert!(err.contains("L12"), "error must name the point: {err}");

    // the supervised entry point degrades gracefully instead
    let outcome = run_plan_supervised(&plan(), &opts).unwrap();
    let report = &outcome.report;
    assert_eq!(report.quarantined.len(), 1);
    let failure = &report.quarantined[0];
    assert_eq!(failure.index, 1);
    assert_eq!(failure.label, "L12");
    assert_eq!(failure.attempts, 2, "1 + max_retries attempts");
    assert!(failure.error.contains("injected fault"));
    // healthy neighbors still published, byte-identical
    for (i, slot) in outcome.results.iter().enumerate() {
        if i == 1 {
            assert!(slot.is_none(), "quarantined slot must stay empty");
        } else {
            let text = slot.as_ref().expect("healthy point").to_cache_text();
            assert_eq!(text, reference[i], "healthy point {i} contaminated");
        }
    }
    let manifest_text = fs::read_to_string(&manifest).expect("FAILED manifest written");
    assert!(manifest_text.contains("L12") && manifest_text.contains("injected fault"));

    // a healthy rerun over the same cache completes the missing point
    // and clears the stale manifest
    let healthy = CampaignOpts {
        faults: None,
        max_retries: 0,
        resume: true,
        ..opts
    };
    let (results, report) = run_plan(&plan(), &healthy).unwrap();
    assert_eq!(report.executed, 1, "only the quarantined point recomputes");
    assert_eq!(report.cache_hits, 3);
    assert_eq!(texts(&results), reference);
    assert!(!manifest.exists(), "healthy run must clear the manifest");
    fs::remove_dir_all(&dir).ok();
}

#[test]
fn on_fault_abort_stops_claiming_after_first_quarantine() {
    // serial worker + the FIRST point persistently failing: under abort
    // no later point may be claimed (under quarantine all of them run)
    let opts = CampaignOpts {
        workers: 1,
        max_retries: 0,
        backoff: Backoff::none(),
        on_fault: OnFault::Abort,
        faults: Some(FaultPlan::new().panic_on("l=10;", u32::MAX)),
        quiet: true,
        ..Default::default()
    };
    let outcome = run_plan_supervised(&plan(), &opts).unwrap();
    assert_eq!(outcome.report.quarantined.len(), 1);
    assert_eq!(outcome.report.quarantined[0].index, 0);
    assert_eq!(outcome.report.executed, 0, "no point after the abort");
    assert!(
        outcome.results.iter().all(|r| r.is_none()),
        "abort must leave every remaining slot unfilled"
    );
}

#[test]
fn cancel_mid_campaign_drains_and_resumes_bitwise() {
    let reference = reference();
    let dir = tmp_dir("drain");
    let cache = dir.join(".cache");

    // pass 1: serial worker, token tripping deterministically mid-plan
    // (each steady point polls once per claim + once per warm/measure
    // step; 100 polls lands inside point 1)
    let cancelled = CampaignOpts {
        workers: 1,
        cancel: Some(CancelToken::after_checks(100)),
        cache_dir: Some(cache.clone()),
        quiet: true,
        ..Default::default()
    };
    let outcome = run_plan_supervised(&plan(), &cancelled).unwrap();
    assert!(outcome.report.cancelled, "token must drain the campaign");
    let completed = outcome.results.iter().filter(|r| r.is_some()).count();
    assert!(
        completed >= 1 && completed < 4,
        "expected a partial drain, got {completed}/4"
    );
    assert_eq!(outcome.report.executed, completed, "completed points stored");

    // the strict wrapper reports the same drain as a typed error
    let err = run_plan(
        &plan(),
        &CampaignOpts {
            cancel: Some(CancelToken::after_checks(1)),
            quiet: true,
            ..Default::default()
        },
    )
    .unwrap_err()
    .to_string();
    assert!(err.contains("--resume"), "unexpected error: {err}");

    // pass 2: resume finishes exactly the remaining points...
    let resume = CampaignOpts {
        workers: 1,
        resume: true,
        cache_dir: Some(cache.clone()),
        quiet: true,
        ..Default::default()
    };
    let (results, report) = run_plan(&plan(), &resume).unwrap();
    assert_eq!(report.cache_hits, completed, "drained points came from cache");
    assert_eq!(report.executed, 4 - completed);
    assert_eq!(
        texts(&results),
        reference,
        "drained + resumed campaign must be byte-identical"
    );

    // pass 3: everything cached, nothing executes
    let (_, report) = run_plan(&plan(), &resume).unwrap();
    assert_eq!(report.executed, 0);
    assert_eq!(report.cache_hits, 4);
    fs::remove_dir_all(&dir).ok();
}

#[test]
fn corrupt_store_fault_recomputes_on_resume() {
    let reference = reference();
    let dir = tmp_dir("corrupt");
    let cache = dir.join(".cache");

    // pass 1: the l=12 entry is bit-flipped right after it publishes
    let opts = CampaignOpts {
        workers: 2,
        faults: Some(FaultPlan::new().corrupt_on("l=12;", 1)),
        cache_dir: Some(cache.clone()),
        quiet: true,
        ..Default::default()
    };
    let (results, report) = run_plan(&plan(), &opts).unwrap();
    assert_eq!(report.executed, 4);
    assert_eq!(texts(&results), reference, "corruption is post-publish only");

    // pass 2: resume detects the damaged entry, counts it, recomputes
    let resume = CampaignOpts {
        faults: None,
        resume: true,
        ..opts
    };
    let (results, report) = run_plan(&plan(), &resume).unwrap();
    assert_eq!(report.corrupt_entries, 1, "the flipped entry must be counted");
    assert_eq!(report.executed, 1, "only the damaged point recomputes");
    assert_eq!(report.cache_hits, 3);
    assert_eq!(texts(&results), reference);

    // pass 3: the repaired cache satisfies everything
    let (_, report) = run_plan(&plan(), &resume).unwrap();
    assert_eq!(report.corrupt_entries, 0);
    assert_eq!(report.executed, 0);
    fs::remove_dir_all(&dir).ok();
}

//! Two-writer cache contention suite (ISSUE 10 satellite): several
//! [`ResultCache`] handles on one directory `store`/`load_checked`
//! concurrently, and a daemon-style reader must only ever observe
//! `Hit` (intact bytes) or `Miss` — never `Corrupt`, never a torn
//! entry, never a failed rename.  The write-tmp-fsync-rename publish
//! protocol plus the pid-scoped sweep (the ISSUE 10 headline bugfix)
//! are what make this hold; the CI serve-smoke job adds the
//! two-process leg (two daemons sharing one cache dir).
//!
//! Test names carry the `cache_contention` prefix on purpose: the CI
//! ThreadSanitizer filter (`sharded pool pe_family kernel
//! cache_contention`) picks them up by substring.

use std::fs;
use std::path::PathBuf;
use std::sync::atomic::{AtomicUsize, Ordering};
use std::sync::Barrier;

use repro::coordinator::fnv1a64;
use repro::runtime::{CacheLoad, ResultCache};

const ROUNDS: usize = 6;
const SPECS: usize = 64;

/// Deterministic payload per spec so any reader can verify integrity
/// byte-for-byte (the daemon's world: content-addressed, deterministic
/// results — concurrent writers of one spec write identical bytes).
fn payload(spec: &str) -> String {
    format!("latticeu {:016x} 0000000000000000\n", fnv1a64(spec))
}

fn spec(i: usize) -> String {
    format!("contend/v1 point={i}")
}

fn tmp_dir(tag: &str) -> PathBuf {
    let dir = std::env::temp_dir().join(format!("repro_cache_contention_{tag}"));
    fs::remove_dir_all(&dir).ok();
    dir
}

#[test]
fn cache_contention_two_writers_one_reader() {
    let dir = tmp_dir("basic");
    // all handles open BEFORE any store: open() must not race a
    // same-process store (the documented own-pid sweep contract)
    let a = ResultCache::open(&dir).unwrap();
    let b = ResultCache::open(&dir).unwrap();
    let reader = ResultCache::open(&dir).unwrap();
    let barrier = Barrier::new(3);
    let done = AtomicUsize::new(0);
    std::thread::scope(|scope| {
        for cache in [&a, &b] {
            let barrier = &barrier;
            let done = &done;
            scope.spawn(move || {
                barrier.wait();
                for _round in 0..ROUNDS {
                    for i in 0..SPECS {
                        let s = spec(i);
                        cache
                            .store(&s, &payload(&s))
                            .expect("store must survive two-writer contention");
                    }
                }
                done.fetch_add(1, Ordering::SeqCst);
            });
        }
        barrier.wait();
        // daemon-style reader polling while both writers hammer the dir
        while done.load(Ordering::SeqCst) < 2 {
            for i in 0..SPECS {
                let s = spec(i);
                match reader.load_checked(&s) {
                    CacheLoad::Hit(p) => {
                        assert_eq!(p, payload(&s), "{s}: reader saw a torn entry")
                    }
                    CacheLoad::Miss => {}
                    CacheLoad::Corrupt => {
                        panic!("{s}: reader saw a corrupt entry under contention")
                    }
                }
            }
        }
    });
    // quiescent state: every spec resolves intact
    for i in 0..SPECS {
        let s = spec(i);
        match reader.load_checked(&s) {
            CacheLoad::Hit(p) => assert_eq!(p, payload(&s)),
            other => panic!("{s}: expected a hit once both writers finished, got {other:?}"),
        }
    }
    // rename-publish leaves no tmp litter behind
    for entry in fs::read_dir(&dir).unwrap().flatten() {
        let name = entry.file_name();
        assert!(
            !name.to_string_lossy().contains(".tmp"),
            "tmp litter left behind: {name:?}"
        );
    }
    fs::remove_dir_all(&dir).ok();
}

#[test]
fn cache_contention_conflicting_writers_never_tear() {
    // two writers race DIFFERENT payloads onto one spec: atomic rename
    // means a reader sees one of the two complete payloads, never a mix
    let dir = tmp_dir("conflict");
    let a = ResultCache::open(&dir).unwrap();
    let b = ResultCache::open(&dir).unwrap();
    let reader = ResultCache::open(&dir).unwrap();
    let clash = "contend/v1 clash";
    let pa = "alpha payload\nwith a second line\n";
    let pb = "beta payload\n";
    let barrier = Barrier::new(3);
    let done = AtomicUsize::new(0);
    std::thread::scope(|scope| {
        for (cache, text) in [(&a, pa), (&b, pb)] {
            let barrier = &barrier;
            let done = &done;
            scope.spawn(move || {
                barrier.wait();
                for _ in 0..200 {
                    cache
                        .store(clash, text)
                        .expect("conflicting stores must both survive");
                }
                done.fetch_add(1, Ordering::SeqCst);
            });
        }
        barrier.wait();
        while done.load(Ordering::SeqCst) < 2 {
            match reader.load_checked(clash) {
                CacheLoad::Hit(p) => assert!(
                    p == pa || p == pb,
                    "reader saw a blend of two payloads: {p:?}"
                ),
                CacheLoad::Miss => {}
                CacheLoad::Corrupt => panic!("reader saw a corrupt entry under contention"),
            }
        }
    });
    match reader.load_checked(clash) {
        CacheLoad::Hit(p) => assert!(p == pa || p == pb),
        other => panic!("expected a winner after the race, got {other:?}"),
    }
    fs::remove_dir_all(&dir).ok();
}

//! Property-based tests of the PDES substrate invariants (own shrinking
//! framework in `prop/`; proptest is unavailable offline).

mod prop;

use prop::{check, PdesCase};
use repro::pdes::{
    BatchPdes, InstrumentedRing, Ising1d, Mode, Model, ModelSpec, RingPdes, ShardedPdes,
    StreamFamily, Topology, VolumeLoad,
};
use repro::rng::Rng;
use repro::stats::{horizon_frame, StepStats};

const CASES: u64 = 60;

/// The topology grid exercised by the generic-engine properties; every
/// entry keeps the case's PE count so masks and horizons line up.
fn case_topologies(c: &PdesCase) -> Vec<Topology> {
    let mut out = vec![Topology::Ring { l: c.l }];
    if c.l > 4 {
        out.push(Topology::KRing { l: c.l, k: 2 });
        out.push(Topology::ScaleFree {
            l: c.l,
            m: 2,
            seed: c.seed,
        });
        out.push(Topology::RandomRegular {
            l: c.l,
            k: 2,
            seed: c.seed,
        });
    }
    out.push(Topology::SmallWorld {
        l: c.l,
        extra: c.l / 3,
        seed: c.seed,
    });
    out
}

/// Causality (Eq. 1): when NV = 1 (every site is a border site) an updated
/// PE was never ahead of either neighbour at decision time.
#[test]
fn causality_never_violated() {
    check::<PdesCase, _>("causality", CASES, |c| {
        if c.rd {
            return Ok(()); // RD modes do not enforce Eq. 1 by design
        }
        let case = PdesCase { nv: 1, ..c.clone() };
        let mut sim = RingPdes::new(case.l, case.load(), case.mode(), Rng::for_stream(case.seed, 0));
        let mut mask = vec![false; case.l];
        for step in 0..case.steps {
            let before = sim.tau().to_vec();
            sim.step_masked(Some(&mut mask));
            for k in 0..case.l {
                if mask[k] {
                    let left = before[(k + case.l - 1) % case.l];
                    let right = before[(k + 1) % case.l];
                    if before[k] > left.min(right) + 1e-15 {
                        return Err(format!("step {step}, PE {k}: updated while ahead"));
                    }
                }
            }
        }
        Ok(())
    });
}

/// Window (Eq. 3): an updated PE was inside the Δ-window at decision time.
#[test]
fn window_never_violated() {
    check::<PdesCase, _>("window", CASES, |c| {
        if !c.delta.is_finite() {
            return Ok(());
        }
        let mut sim = RingPdes::new(c.l, c.load(), c.mode(), Rng::for_stream(c.seed, 0));
        let mut mask = vec![false; c.l];
        for step in 0..c.steps {
            let before = sim.tau().to_vec();
            let gvt = before.iter().copied().fold(f64::INFINITY, f64::min);
            sim.step_masked(Some(&mut mask));
            for k in 0..c.l {
                if mask[k] && before[k] > c.delta + gvt + 1e-12 {
                    return Err(format!("step {step}, PE {k}: updated outside window"));
                }
            }
        }
        Ok(())
    });
}

/// Local times never decrease, idle PEs never move.
#[test]
fn monotone_and_frozen_idle() {
    check::<PdesCase, _>("monotone", CASES, |c| {
        let mut sim = RingPdes::new(c.l, c.load(), c.mode(), Rng::for_stream(c.seed, 0));
        let mut mask = vec![false; c.l];
        for step in 0..c.steps {
            let before = sim.tau().to_vec();
            sim.step_masked(Some(&mut mask));
            for k in 0..c.l {
                let (b, a) = (before[k], sim.tau()[k]);
                if a < b {
                    return Err(format!("step {step}, PE {k}: time decreased"));
                }
                if !mask[k] && a != b {
                    return Err(format!("step {step}, PE {k}: idle PE moved"));
                }
                if mask[k] && a <= b {
                    return Err(format!("step {step}, PE {k}: updated PE did not advance"));
                }
            }
        }
        Ok(())
    });
}

/// Deadlock freedom: at least one PE (the global minimum) updates each step.
#[test]
fn progress_guaranteed() {
    check::<PdesCase, _>("progress", CASES, |c| {
        let mut sim = RingPdes::new(c.l, c.load(), c.mode(), Rng::for_stream(c.seed, 0));
        for step in 0..c.steps {
            if sim.step().n_updated == 0 {
                return Err(format!("step {step}: no PE updated (deadlock)"));
            }
        }
        Ok(())
    });
}

/// Δ = ∞ windowed mode is trajectory-identical to the unconstrained mode
/// (the paper: "an infinite window is equivalent to the absence of the
/// constraint").
#[test]
fn infinite_window_equals_unconstrained() {
    check::<PdesCase, _>("inf_window", CASES, |c| {
        let mk = |mode: Mode| {
            let mut sim = RingPdes::new(c.l, c.load(), mode, Rng::for_stream(c.seed, 1));
            for _ in 0..c.steps {
                sim.step();
            }
            sim.tau().to_vec()
        };
        // Mode::Windowed { delta: inf } normalizes to enforces_window() = false,
        // so both run the identical decision sequence and RNG stream.
        let a = mk(Mode::Conservative);
        let b = mk(Mode::Windowed {
            delta: f64::INFINITY,
        });
        if a != b {
            return Err("trajectories diverged".into());
        }
        Ok(())
    });
}

/// The convex slow/fast decomposition (Eqs. 17-18) holds on every visited
/// horizon, and w_a ≤ w (Jensen).
#[test]
fn decomposition_identities() {
    check::<PdesCase, _>("decomposition", CASES, |c| {
        let mut sim = RingPdes::new(c.l, c.load(), c.mode(), Rng::for_stream(c.seed, 2));
        for step in 0..c.steps {
            let out = sim.step();
            let f = horizon_frame(sim.tau(), out.n_updated);
            let w2_rec = f.f_s * f.w2_s + (1.0 - f.f_s) * f.w2_f;
            if (f.w2 - w2_rec).abs() > 1e-9 * f.w2.max(1.0) {
                return Err(format!("step {step}: Eq. 17 violated"));
            }
            let wa_rec = f.f_s * f.wa_s + (1.0 - f.f_s) * f.wa_f;
            if (f.wa - wa_rec).abs() > 1e-9 * f.wa.max(1.0) {
                return Err(format!("step {step}: Eq. 18 violated"));
            }
            if f.wa > f.w() + 1e-12 {
                return Err(format!("step {step}: w_a > w"));
            }
        }
        Ok(())
    });
}

/// Δ = 0 after desynchronization: only global-minimum PEs may update.
#[test]
fn delta_zero_minimum_only() {
    check::<PdesCase, _>("delta0", CASES, |c| {
        let mode = if c.rd {
            Mode::WindowedRd { delta: 0.0 }
        } else {
            Mode::Windowed { delta: 0.0 }
        };
        let mut sim = RingPdes::new(c.l, VolumeLoad::Sites(1), mode, Rng::for_stream(c.seed, 3));
        sim.step(); // desynchronize
        let mut mask = vec![false; c.l];
        for step in 0..c.steps.min(30) {
            let before = sim.tau().to_vec();
            let gvt = before.iter().copied().fold(f64::INFINITY, f64::min);
            sim.step_masked(Some(&mut mask));
            for k in 0..c.l {
                if mask[k] && before[k] > gvt {
                    return Err(format!("step {step}: non-minimum PE updated at Δ=0"));
                }
            }
        }
        Ok(())
    });
}

/// The batched engine's rows are the serial trials, bit for bit: row i of
/// a B = 3 batch equals a `RingPdes` on the stream (seed, i).
#[test]
fn batch_rows_replay_serial_rings() {
    check::<PdesCase, _>("batch_rows", 25, |c| {
        let mut batch = BatchPdes::with_streams(
            Topology::Ring { l: c.l },
            c.load(),
            c.mode(),
            3,
            c.seed,
            0,
        );
        let mut rings: Vec<RingPdes> = (0..3u64)
            .map(|i| RingPdes::new(c.l, c.load(), c.mode(), Rng::for_stream(c.seed, i)))
            .collect();
        for step in 0..c.steps {
            batch.step();
            for (i, r) in rings.iter_mut().enumerate() {
                let out = r.step();
                if out.n_updated != batch.counts()[i] as usize {
                    return Err(format!("step {step}, row {i}: counts diverged"));
                }
            }
        }
        for (i, r) in rings.iter().enumerate() {
            if batch.tau_row(i) != r.tau() {
                return Err(format!("row {i}: horizons diverged"));
            }
        }
        Ok(())
    });
}

/// The ring view (over the batched engine) is bit-identical to the
/// independently implemented instrumented ring on the same stream —
/// the strongest cross-check that the refactor preserved the paper's
/// event semantics and RNG draw order.
#[test]
fn ring_view_matches_instrumented_reference() {
    check::<PdesCase, _>("ring_vs_instrumented", 25, |c| {
        let mut view = RingPdes::new(c.l, c.load(), c.mode(), Rng::for_stream(c.seed, 0));
        let mut reference = InstrumentedRing::new(c.l, c.load(), c.mode(), Rng::for_stream(c.seed, 0));
        for step in 0..c.steps.min(100) {
            let n_view = view.step().n_updated;
            let n_ref = reference.step();
            if n_view != n_ref {
                return Err(format!("step {step}: {n_view} vs {n_ref} updates"));
            }
            if view.tau() != reference.tau() {
                return Err(format!("step {step}: horizons diverged"));
            }
        }
        Ok(())
    });
}

/// Engine invariants on every topology and every batch row: τ monotone
/// non-decreasing, idle PEs frozen, updated PEs inside their row's
/// Δ-window at decision time, and blocked pending events persisting.
#[test]
fn batch_invariants_hold_per_topology_and_row() {
    check::<PdesCase, _>("batch_invariants", 25, |c| {
        let rows = 2usize;
        for topo in case_topologies(c) {
            let mut sim = BatchPdes::with_streams(topo, c.load(), c.mode(), rows, c.seed, 0);
            let n = rows * c.l;
            let mut mask = vec![false; n];
            for step in 0..c.steps.min(60) {
                let before = sim.tau().to_vec();
                let pend_before: Vec<u8> = (0..rows)
                    .flat_map(|r| sim.pending_row(r).to_vec())
                    .collect();
                let edges: Vec<f64> = (0..rows)
                    .map(|r| {
                        let gvt = before[r * c.l..(r + 1) * c.l]
                            .iter()
                            .copied()
                            .fold(f64::INFINITY, f64::min);
                        c.delta + gvt
                    })
                    .collect();
                sim.step_masked(Some(&mut mask));
                let after = sim.tau();
                let pend_after: Vec<u8> = (0..rows)
                    .flat_map(|r| sim.pending_row(r).to_vec())
                    .collect();
                for i in 0..n {
                    if after[i] < before[i] {
                        return Err(format!("{topo:?} step {step}: time decreased at {i}"));
                    }
                    if !mask[i] && after[i] != before[i] {
                        return Err(format!("{topo:?} step {step}: idle PE {i} moved"));
                    }
                    if mask[i] && after[i] <= before[i] {
                        return Err(format!("{topo:?} step {step}: updated PE {i} stalled"));
                    }
                    if !mask[i] && pend_after[i] != pend_before[i] {
                        return Err(format!("{topo:?} step {step}: blocked PE {i} resampled"));
                    }
                    if mask[i] && c.delta.is_finite() && before[i] > edges[i / c.l] + 1e-12 {
                        return Err(format!("{topo:?} step {step}: PE {i} updated outside window"));
                    }
                }
            }
        }
        Ok(())
    });
}

/// Causality (Eq. 1) generalized: at N_V = 1 an updated PE was never ahead
/// of *any* neighbour of its topology at decision time, on every row.
#[test]
fn causality_never_violated_generic() {
    check::<PdesCase, _>("causality_generic", 25, |c| {
        if c.rd {
            return Ok(()); // RD modes do not enforce Eq. 1 by design
        }
        let case = PdesCase { nv: 1, ..c.clone() };
        let rows = 2usize;
        for topo in case_topologies(&case) {
            let table = topo.neighbour_table();
            let mut sim =
                BatchPdes::with_streams(topo, case.load(), case.mode(), rows, case.seed, 0);
            let mut mask = vec![false; rows * case.l];
            for step in 0..case.steps.min(60) {
                let before = sim.tau().to_vec();
                sim.step_masked(Some(&mut mask));
                for row in 0..rows {
                    for k in 0..case.l {
                        let i = row * case.l + k;
                        if !mask[i] {
                            continue;
                        }
                        for &j in table.neighbours(k) {
                            if before[i] > before[row * case.l + j as usize] + 1e-15 {
                                return Err(format!(
                                    "{topo:?} step {step}, row {row}, PE {k}: updated while ahead"
                                ));
                            }
                        }
                    }
                }
            }
        }
        Ok(())
    });
}

/// The window spread bound holds on every topology: with a finite Δ the
/// per-row horizon spread stays within Δ plus one exponential overshoot.
#[test]
fn window_spread_bounded_per_topology() {
    for topo in [
        Topology::Ring { l: 32 },
        Topology::KRing { l: 32, k: 2 },
        Topology::SmallWorld { l: 32, extra: 10, seed: 77 },
        Topology::Square { side: 6 },
        Topology::Cubic { side: 3 },
    ] {
        let delta = 2.0;
        let mut sim = BatchPdes::with_streams(
            topo,
            VolumeLoad::Sites(1),
            Mode::Windowed { delta },
            3,
            13,
            0,
        );
        for _ in 0..300 {
            sim.step();
        }
        for row in 0..3 {
            let tau = sim.tau_row(row);
            let min = tau.iter().copied().fold(f64::INFINITY, f64::min);
            let max = tau.iter().copied().fold(f64::NEG_INFINITY, f64::max);
            // one exp(1) draw beyond the window edge; 20 is a > 10σ margin
            // at this run length (see ring.rs window test rationale)
            assert!(
                max - min < delta + 20.0,
                "{topo:?} row {row}: spread {}",
                max - min
            );
        }
    }
}

/// Incremental GVT: after *every* step, each row's tracked aggregates
/// (min — the O(1) `global_virtual_time_row` — plus sum, max and the
/// update count) equal a fresh O(L) rescan of the row, bit for bit,
/// across all five topologies, all four modes, and N_V ∈ {1, 10, ∞}.
/// This is the invariant that lets the engine drop the per-step GVT
/// rescan and feed `horizon_frame_fused` straight from the step pass.
#[test]
fn tracked_row_stats_equal_fresh_rescan() {
    let topologies = [
        Topology::Ring { l: 24 },
        Topology::KRing { l: 24, k: 2 },
        Topology::SmallWorld { l: 24, extra: 8, seed: 5 },
        Topology::ScaleFree { l: 24, m: 2, seed: 5 },
        Topology::RandomRegular { l: 24, k: 4, seed: 5 },
        Topology::Square { side: 5 },
        Topology::Cubic { side: 3 },
    ];
    let modes = [
        Mode::Conservative,
        Mode::Windowed { delta: 2.0 },
        Mode::Rd,
        Mode::WindowedRd { delta: 2.0 },
    ];
    let loads = [
        VolumeLoad::Sites(1),
        VolumeLoad::Sites(10),
        VolumeLoad::Infinite,
    ];
    let rows = 2usize;
    for topo in topologies {
        for mode in modes {
            for load in loads {
                let mut sim = BatchPdes::with_streams(topo, load, mode, rows, 31, 0);
                for step in 0..80 {
                    sim.step();
                    for row in 0..rows {
                        let fresh = StepStats::measure(sim.tau_row(row), sim.counts()[row]);
                        let tracked = sim.step_stats_row(row);
                        assert_eq!(
                            tracked, fresh,
                            "{topo:?} {mode:?} {load:?} step {step} row {row}"
                        );
                        assert_eq!(
                            sim.global_virtual_time_row(row).to_bits(),
                            fresh.min.to_bits(),
                            "{topo:?} {mode:?} {load:?} step {step} row {row}: GVT"
                        );
                    }
                }
            }
        }
    }
}

/// THE determinism harness of the domain-decomposed engine (the sharded
/// PR's acceptance bar): for every topology × mode × N_V in the grid and
/// every worker count in {1, 2, 3, 7}, `ShardedPdes` must produce — at
/// *every* step — exactly the bits `BatchPdes` produces: the τ horizon,
/// the pending-event bytes, the per-row update counts, and the tracked
/// `StepStats` (n/sum/min/max).  This is what pins the halo-exchange
/// decision kernels, the per-step barrier placement, and the PE-order
/// update/measurement sweep against any future rework (persistent worker
/// pools, wider halos, ...): a scheduling-dependent read or a reordered
/// RNG draw anywhere shows up here as a bit flip.
#[test]
fn sharded_engine_equals_batch_bit_identical() {
    let topologies = [
        Topology::Ring { l: 24 },
        Topology::KRing { l: 24, k: 2 },
        Topology::SmallWorld { l: 24, extra: 8, seed: 5 },
        Topology::Square { side: 5 },
        Topology::Cubic { side: 3 },
    ];
    let modes = [
        Mode::Conservative,
        Mode::Windowed { delta: 2.0 },
        Mode::Rd,
        Mode::WindowedRd { delta: 2.0 },
    ];
    let loads = [
        VolumeLoad::Sites(1),
        VolumeLoad::Sites(10),
        VolumeLoad::Infinite,
    ];
    let worker_grid = [1usize, 2, 3, 7];
    let rows = 2usize;
    for topo in topologies {
        for mode in modes {
            for load in loads {
                let mut reference =
                    BatchPdes::with_streams(topo, load, mode, rows, 20020601, 0);
                let mut sharded: Vec<ShardedPdes> = worker_grid
                    .iter()
                    .map(|&w| ShardedPdes::with_streams(topo, load, mode, rows, 20020601, 0, w))
                    .collect();
                for step in 0..60 {
                    reference.step();
                    for (&workers, sim) in worker_grid.iter().zip(sharded.iter_mut()) {
                        sim.step();
                        for row in 0..rows {
                            let ctx = format!(
                                "{topo:?} {mode:?} {load:?} workers {workers} step {step} row {row}"
                            );
                            for (k, (a, b)) in reference
                                .tau_row(row)
                                .iter()
                                .zip(sim.tau_row(row))
                                .enumerate()
                            {
                                assert_eq!(a.to_bits(), b.to_bits(), "{ctx}: tau PE {k}");
                            }
                            assert_eq!(
                                reference.pending_row(row),
                                sim.pending_row(row),
                                "{ctx}: pend"
                            );
                            assert_eq!(
                                reference.counts()[row], sim.counts()[row],
                                "{ctx}: counts"
                            );
                            let (s, t) =
                                (reference.step_stats_row(row), sim.step_stats_row(row));
                            assert_eq!(s.n_updated, t.n_updated, "{ctx}: stats.n");
                            assert_eq!(s.sum.to_bits(), t.sum.to_bits(), "{ctx}: stats.sum");
                            assert_eq!(s.min.to_bits(), t.min.to_bits(), "{ctx}: stats.min");
                            assert_eq!(s.max.to_bits(), t.max.to_bits(), "{ctx}: stats.max");
                        }
                    }
                }
            }
        }
    }
}

/// Model-payload twin of the determinism harness: with a payload
/// attached (the Ising payload draws one uniform per event — a new
/// trajectory family — and the SiteCounter draws nothing), `ShardedPdes`
/// must still produce, at every step and for every worker count, exactly
/// the bits `BatchPdes` produces: τ, pend, counts, tracked stats AND the
/// payload state itself (spins / histograms).  This extends the
/// bit-identity contract over the new `apply_event` hook point — a
/// payload call site reading a post-update neighbour where the batch
/// engine read a frozen one, or a reordered model draw, shows up here as
/// a spin flip or a histogram shift.
#[test]
fn model_payload_sharded_equals_batch_bit_identical() {
    let topologies = [
        Topology::Ring { l: 24 },
        Topology::KRing { l: 24, k: 2 },
        Topology::SmallWorld { l: 24, extra: 8, seed: 5 },
    ];
    let modes = [Mode::Conservative, Mode::Windowed { delta: 2.0 }];
    let payloads = [
        // the Ising workload runs at N_V = 1 (neighbour reads need every
        // event checked, see pdes::model docs)...
        (ModelSpec::Ising { beta: 0.7, coupling: 1.0 }, VolumeLoad::Sites(1)),
        // ...the counter payload reads no neighbours, so it also covers
        // the N_V > 1 pending-redraw interleaving
        (ModelSpec::SiteCounter, VolumeLoad::Sites(4)),
    ];
    let worker_grid = [1usize, 2, 3, 7];
    let rows = 2usize;
    for topo in topologies {
        for mode in modes {
            for (model, load) in payloads {
                let mut reference = BatchPdes::with_streams(topo, load, mode, rows, 20020601, 0);
                reference.attach_models(model.build_rows(topo.len(), rows));
                let mut sharded: Vec<ShardedPdes> = worker_grid
                    .iter()
                    .map(|&w| {
                        let mut sim =
                            ShardedPdes::with_streams(topo, load, mode, rows, 20020601, 0, w);
                        sim.attach_models(model.build_rows(topo.len(), rows));
                        sim
                    })
                    .collect();
                for step in 0..50 {
                    reference.step();
                    for (&workers, sim) in worker_grid.iter().zip(sharded.iter_mut()) {
                        sim.step();
                        for row in 0..rows {
                            let ctx = format!(
                                "{topo:?} {mode:?} {} workers {workers} step {step} row {row}",
                                model.tag()
                            );
                            for (k, (a, b)) in reference
                                .tau_row(row)
                                .iter()
                                .zip(sim.tau_row(row))
                                .enumerate()
                            {
                                assert_eq!(a.to_bits(), b.to_bits(), "{ctx}: tau PE {k}");
                            }
                            assert_eq!(
                                reference.pending_row(row),
                                sim.pending_row(row),
                                "{ctx}: pend"
                            );
                            assert_eq!(
                                reference.counts()[row], sim.counts()[row],
                                "{ctx}: counts"
                            );
                            match model {
                                ModelSpec::Ising { .. } => {
                                    let a = reference
                                        .model_row(row)
                                        .unwrap()
                                        .as_any()
                                        .downcast_ref::<Ising1d>()
                                        .unwrap();
                                    let b = sim
                                        .model_row(row)
                                        .unwrap()
                                        .as_any()
                                        .downcast_ref::<Ising1d>()
                                        .unwrap();
                                    assert_eq!(a.spins(), b.spins(), "{ctx}: spins");
                                }
                                ModelSpec::SiteCounter => {
                                    // dyn Model exposes the trait surface
                                    // directly — no downcast needed here
                                    let a =
                                        reference.model_row(row).unwrap().update_stats().unwrap();
                                    let b = sim.model_row(row).unwrap().update_stats().unwrap();
                                    assert_eq!(a, b, "{ctx}: update stats");
                                }
                                ModelSpec::None => unreachable!(),
                            }
                        }
                    }
                }
            }
        }
    }
}

/// The sharded engine's per-shard partials must merge (in shard order) to
/// the tracked row aggregates exactly on the min/max/count lanes — the
/// rule that keeps `global_virtual_time_row` consistent whether it is
/// read O(1) from the row stats or O(workers) from the shard partials.
#[test]
fn sharded_shard_merge_consistent_with_tracked_gvt() {
    for workers in [2usize, 5] {
        let mut sim = ShardedPdes::with_streams(
            Topology::KRing { l: 30, k: 2 },
            VolumeLoad::Sites(4),
            Mode::Windowed { delta: 3.0 },
            3,
            77,
            0,
            workers,
        );
        for _ in 0..50 {
            sim.step();
            for row in 0..3 {
                let merged = sim.merged_shard_stats_row(row);
                let tracked = sim.step_stats_row(row);
                assert_eq!(merged.n_updated, tracked.n_updated);
                assert_eq!(merged.min.to_bits(), tracked.min.to_bits());
                assert_eq!(merged.max.to_bits(), tracked.max.to_bits());
                assert_eq!(
                    sim.gvt_from_shards_row(row).to_bits(),
                    sim.global_virtual_time_row(row).to_bits()
                );
            }
        }
    }
}

/// Per-PE-family twin of THE determinism harness: under
/// `StreamFamily::Pe` every lattice site owns its own counter-based
/// stream, so the update sweep is order-free and the sharded engine can
/// genuinely parallelise *inside* a row — and it must still produce, at
/// every step and for every worker count in {1, 2, 3, 7}, exactly the
/// bits the batch engine produces: τ, pend, counts, and the tracked
/// `StepStats` (which both engines now derive from the same
/// left-to-right `StepStats::measure` fold).  A tile boundary placed one
/// PE off, a shard partial merged in the wrong order, or a stream index
/// derived from anything scheduling-dependent shows up here as a bit
/// flip.
#[test]
fn pe_family_sharded_equals_batch_bit_identical() {
    let topologies = [
        Topology::Ring { l: 24 },
        Topology::KRing { l: 24, k: 2 },
        Topology::SmallWorld { l: 24, extra: 8, seed: 5 },
        Topology::Square { side: 5 },
        Topology::Cubic { side: 3 },
    ];
    let modes = [
        Mode::Conservative,
        Mode::Windowed { delta: 2.0 },
        Mode::Rd,
        Mode::WindowedRd { delta: 2.0 },
    ];
    let loads = [
        VolumeLoad::Sites(1),
        VolumeLoad::Sites(10),
        VolumeLoad::Infinite,
    ];
    let worker_grid = [1usize, 2, 3, 7];
    let rows = 2usize;
    for topo in topologies {
        for mode in modes {
            for load in loads {
                let mut reference = BatchPdes::with_streams_family(
                    topo,
                    load,
                    mode,
                    rows,
                    20020601,
                    0,
                    StreamFamily::Pe,
                );
                let mut sharded: Vec<ShardedPdes> = worker_grid
                    .iter()
                    .map(|&w| {
                        ShardedPdes::with_streams_family(
                            topo,
                            load,
                            mode,
                            rows,
                            20020601,
                            0,
                            w,
                            StreamFamily::Pe,
                        )
                    })
                    .collect();
                for step in 0..60 {
                    reference.step();
                    for (&workers, sim) in worker_grid.iter().zip(sharded.iter_mut()) {
                        sim.step();
                        for row in 0..rows {
                            let ctx = format!(
                                "pe {topo:?} {mode:?} {load:?} workers {workers} step {step} row {row}"
                            );
                            for (k, (a, b)) in reference
                                .tau_row(row)
                                .iter()
                                .zip(sim.tau_row(row))
                                .enumerate()
                            {
                                assert_eq!(a.to_bits(), b.to_bits(), "{ctx}: tau PE {k}");
                            }
                            assert_eq!(
                                reference.pending_row(row),
                                sim.pending_row(row),
                                "{ctx}: pend"
                            );
                            assert_eq!(
                                reference.counts()[row], sim.counts()[row],
                                "{ctx}: counts"
                            );
                            let (s, t) =
                                (reference.step_stats_row(row), sim.step_stats_row(row));
                            assert_eq!(s.n_updated, t.n_updated, "{ctx}: stats.n");
                            assert_eq!(s.sum.to_bits(), t.sum.to_bits(), "{ctx}: stats.sum");
                            assert_eq!(s.min.to_bits(), t.min.to_bits(), "{ctx}: stats.min");
                            assert_eq!(s.max.to_bits(), t.max.to_bits(), "{ctx}: stats.max");
                        }
                    }
                }
            }
        }
    }
}

/// Model-payload twin under the per-PE family: payload rows sweep
/// serially within a row in BOTH engines (payload state mutation is
/// order-dependent) but every event draws from the PE's own stream, so
/// sharded and batch must still agree to the bit on τ, pend, counts AND
/// the payload state (spins / histograms) at every worker count.
#[test]
fn pe_family_model_payload_sharded_equals_batch_bit_identical() {
    let topologies = [
        Topology::Ring { l: 24 },
        Topology::SmallWorld { l: 24, extra: 8, seed: 5 },
    ];
    let modes = [Mode::Conservative, Mode::Windowed { delta: 2.0 }];
    let payloads = [
        (ModelSpec::Ising { beta: 0.7, coupling: 1.0 }, VolumeLoad::Sites(1)),
        (ModelSpec::SiteCounter, VolumeLoad::Sites(4)),
    ];
    let worker_grid = [1usize, 2, 3, 7];
    let rows = 2usize;
    for topo in topologies {
        for mode in modes {
            for (model, load) in payloads {
                let mut reference = BatchPdes::with_streams_family(
                    topo,
                    load,
                    mode,
                    rows,
                    20020601,
                    0,
                    StreamFamily::Pe,
                );
                reference.attach_models(model.build_rows(topo.len(), rows));
                let mut sharded: Vec<ShardedPdes> = worker_grid
                    .iter()
                    .map(|&w| {
                        let mut sim = ShardedPdes::with_streams_family(
                            topo,
                            load,
                            mode,
                            rows,
                            20020601,
                            0,
                            w,
                            StreamFamily::Pe,
                        );
                        sim.attach_models(model.build_rows(topo.len(), rows));
                        sim
                    })
                    .collect();
                for step in 0..50 {
                    reference.step();
                    for (&workers, sim) in worker_grid.iter().zip(sharded.iter_mut()) {
                        sim.step();
                        for row in 0..rows {
                            let ctx = format!(
                                "pe {topo:?} {mode:?} {} workers {workers} step {step} row {row}",
                                model.tag()
                            );
                            for (k, (a, b)) in reference
                                .tau_row(row)
                                .iter()
                                .zip(sim.tau_row(row))
                                .enumerate()
                            {
                                assert_eq!(a.to_bits(), b.to_bits(), "{ctx}: tau PE {k}");
                            }
                            assert_eq!(
                                reference.pending_row(row),
                                sim.pending_row(row),
                                "{ctx}: pend"
                            );
                            match model {
                                ModelSpec::Ising { .. } => {
                                    let a = reference
                                        .model_row(row)
                                        .unwrap()
                                        .as_any()
                                        .downcast_ref::<Ising1d>()
                                        .unwrap();
                                    let b = sim
                                        .model_row(row)
                                        .unwrap()
                                        .as_any()
                                        .downcast_ref::<Ising1d>()
                                        .unwrap();
                                    assert_eq!(a.spins(), b.spins(), "{ctx}: spins");
                                }
                                ModelSpec::SiteCounter => {
                                    let a =
                                        reference.model_row(row).unwrap().update_stats().unwrap();
                                    let b = sim.model_row(row).unwrap().update_stats().unwrap();
                                    assert_eq!(a, b, "{ctx}: update stats");
                                }
                                ModelSpec::None => unreachable!(),
                            }
                        }
                    }
                }
            }
        }
    }
}

/// One long-lived engine cycled through worker counts (the persistent
/// pool's whole point): `re_shard` must keep the trajectory on the exact
/// batch-engine bits at every count, and shrinking must reuse the
/// already-spawned pool instead of building a new one.  This is the
/// property-suite form of the pool-reuse contract — a stale plan, a
/// worker still reading the previous decomposition's block bounds, or a
/// pool that silently respawns per re-shard all fail here.
#[test]
fn pe_family_pool_survives_worker_count_cycling() {
    let topo = Topology::KRing { l: 30, k: 2 };
    let (load, mode, rows) = (VolumeLoad::Sites(4), Mode::Windowed { delta: 3.0 }, 2usize);
    let mut reference =
        BatchPdes::with_streams_family(topo, load, mode, rows, 909, 0, StreamFamily::Pe);
    let mut sim = ShardedPdes::with_streams_family(
        topo,
        load,
        mode,
        rows,
        909,
        0,
        7,
        StreamFamily::Pe,
    );
    let spawned_at_birth = sim.spawned_threads();
    // 7 → 3 → 1 → 5 → 7: every re-shard fits inside the width-7 pool,
    // so no step in the cycle may spawn a thread.
    for &workers in &[7usize, 3, 1, 5, 7] {
        sim = sim.re_shard(workers);
        assert_eq!(
            sim.spawned_threads(),
            spawned_at_birth,
            "re_shard({workers}) respawned the pool"
        );
        for step in 0..20 {
            reference.step();
            sim.step();
            for row in 0..rows {
                let ctx = format!("cycle workers {workers} step {step} row {row}");
                for (k, (a, b)) in
                    reference.tau_row(row).iter().zip(sim.tau_row(row)).enumerate()
                {
                    assert_eq!(a.to_bits(), b.to_bits(), "{ctx}: tau PE {k}");
                }
                assert_eq!(reference.counts()[row], sim.counts()[row], "{ctx}: counts");
            }
        }
    }
}

/// Dynamic-Δ drift harness (the autotune PR's engine acceptance bar):
/// cycling `set_delta` mid-run must leave the tracked per-row aggregates
/// bit-equal to a fresh O(L) rescan after *every* subsequent step — on
/// every topology (including the new quenched families), every mode
/// family, and with the sharded engine tracking the batch engine bit for
/// bit through every Δ change at every worker count.  A stale window
/// edge cached across the change, a shard reading the old mode, or a
/// tracked aggregate not re-derived per sweep all fail here.
#[test]
fn dynamic_delta_keeps_tracked_stats_and_sharded_identity() {
    let topologies = [
        Topology::Ring { l: 24 },
        Topology::KRing { l: 24, k: 2 },
        Topology::SmallWorld { l: 24, extra: 8, seed: 5 },
        Topology::ScaleFree { l: 24, m: 2, seed: 5 },
        Topology::RandomRegular { l: 24, k: 4, seed: 5 },
    ];
    let modes = [
        Mode::Conservative,
        Mode::Windowed { delta: 2.0 },
        Mode::Rd,
        Mode::WindowedRd { delta: 2.0 },
    ];
    // expand, shrink, and a mid-range settle — the shapes the autotune
    // controller's probe sequence actually produces
    let schedule = [0.5, 8.0, 2.0];
    let worker_grid = [1usize, 3, 7];
    let rows = 2usize;
    for topo in topologies {
        for mode in modes {
            let mut reference =
                BatchPdes::with_streams(topo, VolumeLoad::Sites(1), mode, rows, 20020601, 0);
            let mut sharded: Vec<ShardedPdes> = worker_grid
                .iter()
                .map(|&w| {
                    ShardedPdes::with_streams(
                        topo,
                        VolumeLoad::Sites(1),
                        mode,
                        rows,
                        20020601,
                        0,
                        w,
                    )
                })
                .collect();
            let mut phases: Vec<Option<f64>> = vec![None];
            phases.extend(schedule.iter().map(|&d| Some(d)));
            for (pi, retune) in phases.into_iter().enumerate() {
                if let Some(delta) = retune {
                    reference.set_delta(delta);
                    for sim in sharded.iter_mut() {
                        sim.set_delta(delta);
                    }
                }
                for step in 0..15 {
                    reference.step();
                    for row in 0..rows {
                        // tracked aggregates == fresh rescan, bit for bit
                        let fresh =
                            StepStats::measure(reference.tau_row(row), reference.counts()[row]);
                        assert_eq!(
                            reference.step_stats_row(row),
                            fresh,
                            "{topo:?} {mode:?} phase {pi} step {step} row {row}: tracked drift"
                        );
                    }
                    for (&workers, sim) in worker_grid.iter().zip(sharded.iter_mut()) {
                        sim.step();
                        for row in 0..rows {
                            let ctx = format!(
                                "{topo:?} {mode:?} phase {pi} workers {workers} step {step} row {row}"
                            );
                            for (k, (a, b)) in reference
                                .tau_row(row)
                                .iter()
                                .zip(sim.tau_row(row))
                                .enumerate()
                            {
                                assert_eq!(a.to_bits(), b.to_bits(), "{ctx}: tau PE {k}");
                            }
                            assert_eq!(
                                reference.pending_row(row),
                                sim.pending_row(row),
                                "{ctx}: pend"
                            );
                            let (s, t) =
                                (reference.step_stats_row(row), sim.step_stats_row(row));
                            assert_eq!(s, t, "{ctx}: stats");
                        }
                    }
                }
            }
        }
    }
}

/// Degree-distribution and connectivity properties of the quenched
/// network families, across seeds and sizes (all deterministic, so a
/// passing grid stays passing forever):
/// * both families build symmetric (undirected) simple graphs;
/// * scale-free (preferential attachment): connected by construction,
///   minimum degree ≥ m, and the hub degree strictly exceeds the
///   attachment count (heavy tail exists);
/// * random-regular (configuration model): exactly k-regular.
#[test]
fn quenched_family_degree_and_connectivity_properties() {
    fn symmetric_and_simple(table: &repro::pdes::NeighbourTable, l: usize) {
        for k in 0..l {
            let nbrs = table.neighbours(k);
            let mut seen = std::collections::BTreeSet::new();
            for &j in nbrs {
                assert_ne!(j as usize, k, "self-loop at PE {k}");
                assert!(seen.insert(j), "duplicate edge {k}-{j}");
                assert!(
                    table.neighbours(j as usize).contains(&(k as u32)),
                    "asymmetric edge {k}->{j}"
                );
            }
        }
    }
    fn connected(table: &repro::pdes::NeighbourTable, l: usize) -> bool {
        let mut seen = vec![false; l];
        let mut stack = vec![0usize];
        seen[0] = true;
        let mut count = 1;
        while let Some(k) = stack.pop() {
            for &j in table.neighbours(k) {
                if !seen[j as usize] {
                    seen[j as usize] = true;
                    count += 1;
                    stack.push(j as usize);
                }
            }
        }
        count == l
    }

    for seed in [1u64, 7, 42, 20020601] {
        for l in [8usize, 16, 32, 64] {
            let m = 2;
            let sf = Topology::ScaleFree { l, m, seed }.neighbour_table();
            symmetric_and_simple(&sf, l);
            assert!(connected(&sf, l), "sf l={l} seed={seed} disconnected");
            let degrees: Vec<usize> = (0..l).map(|k| sf.neighbours(k).len()).collect();
            assert!(
                degrees.iter().all(|&d| d >= m),
                "sf l={l} seed={seed}: degree below m"
            );
            let hub = *degrees.iter().max().unwrap();
            assert!(hub > m, "sf l={l} seed={seed}: no hub (max degree {hub})");

            let k = 4;
            let rr = Topology::RandomRegular { l, k, seed }.neighbour_table();
            symmetric_and_simple(&rr, l);
            for pe in 0..l {
                assert_eq!(
                    rr.neighbours(pe).len(),
                    k,
                    "rr l={l} seed={seed}: PE {pe} not {k}-regular"
                );
            }
            // k >= 3 random regular graphs at these sizes: the pinned
            // seeds all produce connected graphs (deterministic check)
            assert!(connected(&rr, l), "rr l={l} seed={seed} disconnected");
        }
    }
}

/// Determinism: the same seed replays the same trajectory.
#[test]
fn deterministic_replay() {
    check::<PdesCase, _>("determinism", 20, |c| {
        let run = || {
            let mut sim = RingPdes::new(c.l, c.load(), c.mode(), Rng::for_stream(c.seed, 4));
            for _ in 0..c.steps {
                sim.step();
            }
            sim.tau().to_vec()
        };
        if run() != run() {
            return Err("replay diverged".into());
        }
        Ok(())
    });
}

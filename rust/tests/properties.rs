//! Property-based tests of the PDES substrate invariants (own shrinking
//! framework in `prop/`; proptest is unavailable offline).

mod prop;

use prop::{check, PdesCase};
use repro::pdes::{Mode, RingPdes, VolumeLoad};
use repro::rng::Rng;
use repro::stats::horizon_frame;

const CASES: u64 = 60;

/// Causality (Eq. 1): when NV = 1 (every site is a border site) an updated
/// PE was never ahead of either neighbour at decision time.
#[test]
fn causality_never_violated() {
    check::<PdesCase, _>("causality", CASES, |c| {
        if c.rd {
            return Ok(()); // RD modes do not enforce Eq. 1 by design
        }
        let case = PdesCase { nv: 1, ..c.clone() };
        let mut sim = RingPdes::new(case.l, case.load(), case.mode(), Rng::for_stream(case.seed, 0));
        let mut mask = vec![false; case.l];
        for step in 0..case.steps {
            let before = sim.tau().to_vec();
            sim.step_masked(Some(&mut mask));
            for k in 0..case.l {
                if mask[k] {
                    let left = before[(k + case.l - 1) % case.l];
                    let right = before[(k + 1) % case.l];
                    if before[k] > left.min(right) + 1e-15 {
                        return Err(format!("step {step}, PE {k}: updated while ahead"));
                    }
                }
            }
        }
        Ok(())
    });
}

/// Window (Eq. 3): an updated PE was inside the Δ-window at decision time.
#[test]
fn window_never_violated() {
    check::<PdesCase, _>("window", CASES, |c| {
        if !c.delta.is_finite() {
            return Ok(());
        }
        let mut sim = RingPdes::new(c.l, c.load(), c.mode(), Rng::for_stream(c.seed, 0));
        let mut mask = vec![false; c.l];
        for step in 0..c.steps {
            let before = sim.tau().to_vec();
            let gvt = before.iter().copied().fold(f64::INFINITY, f64::min);
            sim.step_masked(Some(&mut mask));
            for k in 0..c.l {
                if mask[k] && before[k] > c.delta + gvt + 1e-12 {
                    return Err(format!("step {step}, PE {k}: updated outside window"));
                }
            }
        }
        Ok(())
    });
}

/// Local times never decrease, idle PEs never move.
#[test]
fn monotone_and_frozen_idle() {
    check::<PdesCase, _>("monotone", CASES, |c| {
        let mut sim = RingPdes::new(c.l, c.load(), c.mode(), Rng::for_stream(c.seed, 0));
        let mut mask = vec![false; c.l];
        for step in 0..c.steps {
            let before = sim.tau().to_vec();
            sim.step_masked(Some(&mut mask));
            for k in 0..c.l {
                let (b, a) = (before[k], sim.tau()[k]);
                if a < b {
                    return Err(format!("step {step}, PE {k}: time decreased"));
                }
                if !mask[k] && a != b {
                    return Err(format!("step {step}, PE {k}: idle PE moved"));
                }
                if mask[k] && a <= b {
                    return Err(format!("step {step}, PE {k}: updated PE did not advance"));
                }
            }
        }
        Ok(())
    });
}

/// Deadlock freedom: at least one PE (the global minimum) updates each step.
#[test]
fn progress_guaranteed() {
    check::<PdesCase, _>("progress", CASES, |c| {
        let mut sim = RingPdes::new(c.l, c.load(), c.mode(), Rng::for_stream(c.seed, 0));
        for step in 0..c.steps {
            if sim.step().n_updated == 0 {
                return Err(format!("step {step}: no PE updated (deadlock)"));
            }
        }
        Ok(())
    });
}

/// Δ = ∞ windowed mode is trajectory-identical to the unconstrained mode
/// (the paper: "an infinite window is equivalent to the absence of the
/// constraint").
#[test]
fn infinite_window_equals_unconstrained() {
    check::<PdesCase, _>("inf_window", CASES, |c| {
        let mk = |mode: Mode| {
            let mut sim = RingPdes::new(c.l, c.load(), mode, Rng::for_stream(c.seed, 1));
            for _ in 0..c.steps {
                sim.step();
            }
            sim.tau().to_vec()
        };
        // Mode::Windowed { delta: inf } normalizes to enforces_window() = false,
        // so both run the identical decision sequence and RNG stream.
        let a = mk(Mode::Conservative);
        let b = mk(Mode::Windowed {
            delta: f64::INFINITY,
        });
        if a != b {
            return Err("trajectories diverged".into());
        }
        Ok(())
    });
}

/// The convex slow/fast decomposition (Eqs. 17-18) holds on every visited
/// horizon, and w_a ≤ w (Jensen).
#[test]
fn decomposition_identities() {
    check::<PdesCase, _>("decomposition", CASES, |c| {
        let mut sim = RingPdes::new(c.l, c.load(), c.mode(), Rng::for_stream(c.seed, 2));
        for step in 0..c.steps {
            let out = sim.step();
            let f = horizon_frame(sim.tau(), out.n_updated);
            let w2_rec = f.f_s * f.w2_s + (1.0 - f.f_s) * f.w2_f;
            if (f.w2 - w2_rec).abs() > 1e-9 * f.w2.max(1.0) {
                return Err(format!("step {step}: Eq. 17 violated"));
            }
            let wa_rec = f.f_s * f.wa_s + (1.0 - f.f_s) * f.wa_f;
            if (f.wa - wa_rec).abs() > 1e-9 * f.wa.max(1.0) {
                return Err(format!("step {step}: Eq. 18 violated"));
            }
            if f.wa > f.w() + 1e-12 {
                return Err(format!("step {step}: w_a > w"));
            }
        }
        Ok(())
    });
}

/// Δ = 0 after desynchronization: only global-minimum PEs may update.
#[test]
fn delta_zero_minimum_only() {
    check::<PdesCase, _>("delta0", CASES, |c| {
        let mode = if c.rd {
            Mode::WindowedRd { delta: 0.0 }
        } else {
            Mode::Windowed { delta: 0.0 }
        };
        let mut sim = RingPdes::new(c.l, VolumeLoad::Sites(1), mode, Rng::for_stream(c.seed, 3));
        sim.step(); // desynchronize
        let mut mask = vec![false; c.l];
        for step in 0..c.steps.min(30) {
            let before = sim.tau().to_vec();
            let gvt = before.iter().copied().fold(f64::INFINITY, f64::min);
            sim.step_masked(Some(&mut mask));
            for k in 0..c.l {
                if mask[k] && before[k] > gvt {
                    return Err(format!("step {step}: non-minimum PE updated at Δ=0"));
                }
            }
        }
        Ok(())
    });
}

/// Determinism: the same seed replays the same trajectory.
#[test]
fn deterministic_replay() {
    check::<PdesCase, _>("determinism", 20, |c| {
        let run = || {
            let mut sim = RingPdes::new(c.l, c.load(), c.mode(), Rng::for_stream(c.seed, 4));
            for _ in 0..c.steps {
                sim.step();
            }
            sim.tau().to_vec()
        };
        if run() != run() {
            return Err("replay diverged".into());
        }
        Ok(())
    });
}

//! End-to-end protocol suite for the `repro serve` daemon (ISSUE 10
//! acceptance): identical submissions from concurrent clients dedupe to
//! one engine execution with byte-identical streamed results, a
//! restarted daemon serves resubmissions entirely from cache
//! (`executed=0`), malformed input gets `error` lines instead of
//! disconnects, a drain mid-execution fails only the subscribers and
//! leaves a bitwise-resumable cache, and plan submissions expand and
//! stream in plan order.
//!
//! All servers bind `127.0.0.1:0` (ephemeral ports) and are cancelled
//! through a plain [`CancelToken`] — the signal-backed token is CLI
//! wiring, exercised by the CI serve-smoke job.

use std::fs;
use std::io::{BufRead, BufReader, Write};
use std::net::TcpStream;
use std::path::{Path, PathBuf};
use std::sync::{Arc, Barrier};
use std::thread::JoinHandle;
use std::time::Duration;

use repro::coordinator::{
    fnv1a64, submit, CancelToken, Control, FaultPlan, Profile, RunSpec, ServeOpts, ServeReport,
    Server, SubmitSummary, SweepPlan, SweepPoint,
};
use repro::pdes::{Mode, StreamFamily, Topology, VolumeLoad};
use repro::runtime::{CacheLoad, ResultCache};

/// A steady point small enough to execute in milliseconds; `tag` varies
/// the seed so tests never share cache identities.
fn tiny_point(tag: u64) -> SweepPoint {
    SweepPoint::steady(
        format!("serve{tag}"),
        Topology::Ring { l: 8 },
        RunSpec {
            l: 8,
            load: VolumeLoad::Sites(1),
            mode: Mode::Windowed { delta: 10.0 },
            trials: 2,
            steps: 0,
            seed: 100 + tag,
            streams: StreamFamily::Pe,
            control: Control::Static,
        },
        5,
        10,
    )
}

fn tmp_dir(tag: &str) -> PathBuf {
    let dir = std::env::temp_dir().join(format!("repro_serve_{tag}"));
    fs::remove_dir_all(&dir).ok();
    dir
}

/// Bind an ephemeral-port daemon and run it on a background thread;
/// returns the dialable address, the cancel handle, and the report join.
fn start_server(
    dir: &Path,
    mutate: impl FnOnce(&mut ServeOpts),
) -> (String, CancelToken, JoinHandle<ServeReport>) {
    let mut opts = ServeOpts {
        addr: "127.0.0.1:0".to_string(),
        cache_dir: dir.to_path_buf(),
        quiet: true,
        ..ServeOpts::default()
    };
    mutate(&mut opts);
    let server = Server::bind(opts).expect("bind ephemeral port");
    let addr = server.local_addr().expect("local addr").to_string();
    let cancel = CancelToken::new();
    let run_cancel = cancel.clone();
    let handle = std::thread::spawn(move || server.run(run_cancel).expect("server run"));
    (addr, cancel, handle)
}

#[test]
fn identical_submissions_dedupe_to_one_execution() {
    let dir = tmp_dir("dedupe");
    let point = tiny_point(1);
    let spec = point.spec();
    // hold the single execution open so the second client reliably
    // arrives while the point is still in flight
    let faults = FaultPlan::new().delay_on(&spec, 700, 1);
    let (addr, cancel, handle) = start_server(&dir, |o| o.faults = Some(faults));
    let cmd = vec![format!("point {spec}")];
    let barrier = Arc::new(Barrier::new(2));
    let logs: Vec<String> = std::thread::scope(|scope| {
        let clients: Vec<_> = (0..2)
            .map(|_| {
                let addr = addr.clone();
                let cmd = cmd.clone();
                let barrier = Arc::clone(&barrier);
                scope.spawn(move || {
                    barrier.wait();
                    let mut log = Vec::new();
                    let summary = submit(&addr, &cmd, &mut log).expect("submit");
                    assert_eq!(
                        summary,
                        SubmitSummary {
                            results: 1,
                            failed: 0
                        }
                    );
                    String::from_utf8(log).expect("utf8 stream")
                })
            })
            .collect();
        clients.into_iter().map(|c| c.join().unwrap()).collect()
    });
    assert_eq!(
        logs[0], logs[1],
        "both subscribers must read byte-identical streams"
    );
    assert!(logs[0].contains("ack 1"), "{}", logs[0]);
    assert!(logs[0].contains("done 1"), "{}", logs[0]);
    cancel.cancel();
    let report = handle.join().unwrap();
    assert_eq!(
        report.executed, 1,
        "two identical submissions, one engine execution: {report:?}"
    );
    assert_eq!(report.submitted, 2);
    assert_eq!(
        report.direct_hits + report.joined + report.batch_hits,
        1,
        "the twin submission must resolve without a fresh execution: {report:?}"
    );
    assert_eq!(report.failed, 0);
    fs::remove_dir_all(&dir).ok();
}

#[test]
fn restart_serves_resubmissions_from_cache_without_the_engine() {
    let dir = tmp_dir("restart");
    let spec = tiny_point(2).spec();
    let cmd = vec![format!("point {spec}")];

    let (addr, cancel, handle) = start_server(&dir, |_| {});
    let mut cold = Vec::new();
    assert_eq!(
        submit(&addr, &cmd, &mut cold).expect("cold submit"),
        SubmitSummary {
            results: 1,
            failed: 0
        }
    );
    cancel.cancel();
    assert_eq!(handle.join().unwrap().executed, 1);

    // a fresh daemon over the same cache dir: pure hit, engine untouched
    let (addr, cancel, handle) = start_server(&dir, |_| {});
    let mut warm = Vec::new();
    assert_eq!(
        submit(&addr, &cmd, &mut warm).expect("warm submit"),
        SubmitSummary {
            results: 1,
            failed: 0
        }
    );
    cancel.cancel();
    let report = handle.join().unwrap();
    assert_eq!(
        report.executed, 0,
        "post-restart resubmission must be served entirely from cache: {report:?}"
    );
    assert_eq!(report.direct_hits, 1);
    assert_eq!(
        cold, warm,
        "executed and cache-served streams must be byte-identical"
    );
    fs::remove_dir_all(&dir).ok();
}

#[test]
fn malformed_input_gets_error_lines_not_disconnects() {
    let dir = tmp_dir("errors");
    let (addr, cancel, handle) = start_server(&dir, |_| {});
    let stream = TcpStream::connect(&addr).expect("connect");
    let mut writer = stream.try_clone().expect("clone");
    let mut reader = BufReader::new(stream);
    let mut line = String::new();
    reader.read_line(&mut line).unwrap();
    assert!(line.starts_with("repro-serve/1"), "{line:?}");

    writeln!(writer, "frobnicate").unwrap();
    line.clear();
    reader.read_line(&mut line).unwrap();
    assert!(line.starts_with("error unknown command"), "{line:?}");

    writeln!(writer, "point repro/v1 topo=ring:8 run=nonsense").unwrap();
    line.clear();
    reader.read_line(&mut line).unwrap();
    assert!(line.starts_with("error "), "{line:?}");
    assert!(!line.contains('\r'), "errors must stay single-line");

    // no resolver injected: plan submissions are refused, not fatal
    writeln!(writer, "plan fig2").unwrap();
    line.clear();
    reader.read_line(&mut line).unwrap();
    assert!(line.contains("no plan registry"), "{line:?}");

    writeln!(writer, "stats").unwrap();
    line.clear();
    reader.read_line(&mut line).unwrap();
    assert!(line.starts_with("stats submitted=0 "), "{line:?}");

    writeln!(writer, "bye").unwrap();
    line.clear();
    reader.read_line(&mut line).unwrap();
    assert_eq!(line.trim_end(), "bye");

    cancel.cancel();
    let report = handle.join().unwrap();
    assert_eq!(report.submitted, 0);
    assert_eq!(report.executed, 0);
    fs::remove_dir_all(&dir).ok();
}

#[test]
fn drain_fails_subscribers_and_leaves_a_resumable_cache() {
    let dir = tmp_dir("drain");
    let spec = tiny_point(4).spec();
    // park the execution long enough to cancel mid-flight
    let faults = FaultPlan::new().delay_on(&spec, 1200, 1);
    let (addr, cancel, handle) = start_server(&dir, |o| o.faults = Some(faults));
    let client = {
        let addr = addr.clone();
        let cmd = vec![format!("point {spec}")];
        std::thread::spawn(move || {
            let mut log = Vec::new();
            let summary = submit(&addr, &cmd, &mut log).expect("submit");
            (summary, String::from_utf8(log).expect("utf8 stream"))
        })
    };
    // let the batch claim the point, then pull the plug mid-delay
    std::thread::sleep(Duration::from_millis(500));
    cancel.cancel();
    let (summary, log) = client.join().unwrap();
    assert_eq!(summary.results, 0);
    assert_eq!(summary.failed, 1, "subscriber must hear the drain: {log}");
    assert!(log.contains("daemon is draining"), "{log}");
    let report = handle.join().unwrap();
    assert_eq!(
        report.executed, 0,
        "an interrupted point must not count as executed: {report:?}"
    );
    assert_eq!(report.failed, 1);

    // steps are atomic: the interrupted point left no cache entry...
    let cache = ResultCache::open(&dir).expect("reopen cache");
    assert!(matches!(cache.load_checked(&spec), CacheLoad::Miss));
    drop(cache);

    // ...and a restarted daemon executes it cleanly from the same dir
    let (addr, cancel, handle) = start_server(&dir, |_| {});
    let mut log = Vec::new();
    assert_eq!(
        submit(&addr, &[format!("point {spec}")], &mut log).expect("resubmit"),
        SubmitSummary {
            results: 1,
            failed: 0
        }
    );
    cancel.cancel();
    assert_eq!(handle.join().unwrap().executed, 1);
    fs::remove_dir_all(&dir).ok();
}

/// Plan registry stand-in for the daemon under test (the CLI injects
/// `experiments::plan_for` here).
fn test_resolver(name: &str, _profile: &Profile) -> Option<SweepPlan> {
    if name != "tinyplan" {
        return None;
    }
    let mut plan = SweepPlan::new("tinyplan", "serve protocol test plan");
    plan.push(tiny_point(50));
    plan.push(tiny_point(51));
    Some(plan)
}

#[test]
fn plan_submissions_expand_and_stream_in_plan_order() {
    let dir = tmp_dir("plan");
    let (addr, cancel, handle) = start_server(&dir, |o| o.resolver = Some(test_resolver));

    let mut log = Vec::new();
    let summary = submit(&addr, &["plan tinyplan".to_string()], &mut log).expect("submit");
    let log = String::from_utf8(log).expect("utf8 stream");
    assert_eq!(
        summary,
        SubmitSummary {
            results: 2,
            failed: 0
        }
    );
    assert!(log.contains("ack 2"), "{log}");
    assert!(log.contains("done 2"), "{log}");
    // results stream in plan order regardless of completion order
    let first = format!("result {:016x}", fnv1a64(&tiny_point(50).spec()));
    let second = format!("result {:016x}", fnv1a64(&tiny_point(51).spec()));
    let p0 = log.find(&first).expect("first point's result header");
    let p1 = log.find(&second).expect("second point's result header");
    assert!(p0 < p1, "plan order must be preserved: {log}");

    // unknown names are an error line, not a hangup
    let mut elog = Vec::new();
    let es = submit(&addr, &["plan nope".to_string()], &mut elog).expect("unknown plan");
    assert_eq!(es, SubmitSummary::default());
    assert!(
        String::from_utf8(elog).unwrap().contains("error unknown plan"),
        "unknown plan must produce an error line"
    );

    cancel.cancel();
    let report = handle.join().unwrap();
    assert_eq!(report.executed, 2);
    assert_eq!(report.submitted, 2);
    fs::remove_dir_all(&dir).ok();
}

//! Exact-equality proof of the decision-kernel dispatch (ISSUE 9): the
//! scalar, SIMD and auto kernels must produce **bit-identical**
//! trajectories — τ, pending classes, counts and tracked [`StepStats`]
//! compared to the bit — across topologies × modes × batch heights ×
//! stream families, on both engines and for every worker count tested.
//!
//! B ∈ {1, 3, 8} is deliberate: 1 and 3 are not multiples of the lane
//! width (LANE = 4), so partial lane groups (the scalar tail path) are
//! pinned alongside full groups (B = 8 = two full AVX2 groups).
//!
//! On machines without AVX2 the SIMD request clamps to scalar
//! (`BatchPdes::set_decide_kernel`), so the suite stays green — vacuously
//! for the SIMD half — and CI's `-Ctarget-cpu=native` kernel-smoke leg
//! provides the non-vacuous run.

use repro::pdes::{
    ActiveKernel, BatchPdes, Mode, ShardedPdes, StreamFamily, Topology, VolumeLoad,
};

const STEPS: usize = 30;
const SEED: u64 = 90210;

fn topologies() -> [Topology; 5] {
    [
        Topology::Ring { l: 24 },
        Topology::KRing { l: 24, k: 2 },
        Topology::SmallWorld { l: 24, extra: 8, seed: 3 },
        Topology::ScaleFree { l: 24, m: 2, seed: 5 },
        Topology::RandomRegular { l: 24, k: 4, seed: 7 },
    ]
}

fn modes() -> [Mode; 4] {
    [
        Mode::Conservative,
        Mode::Windowed { delta: 2.0 },
        Mode::Rd,
        Mode::WindowedRd { delta: 1.5 },
    ]
}

/// Bit-faithful trajectory snapshot: τ and stats as raw u64 bits so the
/// comparison is exact equality, not an epsilon.
#[derive(PartialEq, Eq, Debug)]
struct Snapshot {
    tau_bits: Vec<u64>,
    pend: Vec<u8>,
    counts: Vec<u32>,
    stats_bits: Vec<(u32, u64, u64, u64)>,
}

fn snapshot(sim: &BatchPdes) -> Snapshot {
    Snapshot {
        tau_bits: sim.tau().iter().map(|t| t.to_bits()).collect(),
        pend: (0..sim.rows())
            .flat_map(|r| sim.pending_row(r).to_vec())
            .collect(),
        counts: sim.counts().to_vec(),
        stats_bits: sim
            .step_stats()
            .iter()
            .map(|s| {
                (
                    s.n_updated,
                    s.sum.to_bits(),
                    s.min.to_bits(),
                    s.max.to_bits(),
                )
            })
            .collect(),
    }
}

fn run_batch(
    topo: Topology,
    load: VolumeLoad,
    mode: Mode,
    rows: usize,
    family: StreamFamily,
    kernel: Option<ActiveKernel>,
) -> Snapshot {
    let mut sim = BatchPdes::with_streams_family(topo, load, mode, rows, SEED, 0, family);
    if let Some(k) = kernel {
        sim.set_decide_kernel(k);
    }
    for _ in 0..STEPS {
        sim.step();
    }
    snapshot(&sim)
}

fn run_sharded(
    topo: Topology,
    load: VolumeLoad,
    mode: Mode,
    rows: usize,
    family: StreamFamily,
    kernel: ActiveKernel,
    workers: usize,
) -> Snapshot {
    let mut sim =
        ShardedPdes::with_streams_family(topo, load, mode, rows, SEED, 0, workers, family);
    sim.set_decide_kernel(kernel);
    for _ in 0..STEPS {
        sim.step();
    }
    snapshot(&sim)
}

fn grid_check_family(family: StreamFamily) {
    for topo in topologies() {
        for mode in modes() {
            for load in [VolumeLoad::Sites(1), VolumeLoad::Sites(3)] {
                for rows in [1usize, 3, 8] {
                    let base = run_batch(topo, load, mode, rows, family, Some(ActiveKernel::Scalar));
                    let simd =
                        run_batch(topo, load, mode, rows, family, Some(ActiveKernel::SimdAvx2));
                    assert_eq!(
                        base, simd,
                        "scalar vs simd diverged: {topo:?} {mode:?} {load:?} B={rows} {family:?}"
                    );
                    let auto = run_batch(topo, load, mode, rows, family, None);
                    assert_eq!(
                        base, auto,
                        "scalar vs auto diverged: {topo:?} {mode:?} {load:?} B={rows} {family:?}"
                    );
                }
            }
        }
    }
}

#[test]
fn kernel_dispatch_is_bit_exact_across_the_grid_rowv1() {
    grid_check_family(StreamFamily::RowV1);
}

#[test]
fn kernel_dispatch_is_bit_exact_across_the_grid_pe() {
    grid_check_family(StreamFamily::Pe);
}

#[test]
fn kernel_dispatch_is_bit_exact_on_the_sharded_engine() {
    // sharded lane-blocked column strips vs the batch whole-row kernel,
    // per kernel, per worker count — a narrower (topology, mode) slice
    // than the batch grid since every (kernel, workers) pair multiplies
    for topo in [
        Topology::Ring { l: 24 },
        Topology::KRing { l: 24, k: 2 },
        Topology::SmallWorld { l: 24, extra: 8, seed: 3 },
    ] {
        for mode in [Mode::Conservative, Mode::Windowed { delta: 2.0 }] {
            for family in [StreamFamily::RowV1, StreamFamily::Pe] {
                for rows in [1usize, 3, 8] {
                    let load = VolumeLoad::Sites(3);
                    let base = run_batch(topo, load, mode, rows, family, Some(ActiveKernel::Scalar));
                    for workers in [1usize, 4] {
                        for kernel in [ActiveKernel::Scalar, ActiveKernel::SimdAvx2] {
                            let got = run_sharded(topo, load, mode, rows, family, kernel, workers);
                            assert_eq!(
                                base, got,
                                "sharded diverged: {topo:?} {mode:?} B={rows} {family:?} \
                                 W={workers} {kernel:?}"
                            );
                        }
                    }
                }
            }
        }
    }
}

#[test]
fn kernel_simd_request_clamps_to_scalar_without_avx2() {
    let mut sim = BatchPdes::with_streams(
        Topology::Ring { l: 8 },
        VolumeLoad::Sites(1),
        Mode::Conservative,
        2,
        1,
        0,
    );
    sim.set_decide_kernel(ActiveKernel::SimdAvx2);
    if repro::pdes::simd_supported() {
        assert_eq!(sim.decide_kernel(), ActiveKernel::SimdAvx2);
    } else {
        // the dispatch-safety invariant: SimdAvx2 never survives on a
        // machine where the AVX2 kernel could not legally run
        assert_eq!(sim.decide_kernel(), ActiveKernel::Scalar);
    }
    sim.set_decide_kernel(ActiveKernel::Scalar);
    assert_eq!(sim.decide_kernel(), ActiveKernel::Scalar);
}

#[test]
fn kernel_decide_only_is_trajectory_invisible() {
    // interleaving decide_only() between steps must not perturb the
    // trajectory: the decision pass is RNG-free and idempotent
    let topo = Topology::KRing { l: 20, k: 2 };
    let (load, mode) = (VolumeLoad::Sites(4), Mode::Windowed { delta: 3.0 });
    let mut plain = BatchPdes::with_streams(topo, load, mode, 5, 11, 0);
    let mut probed = BatchPdes::with_streams(topo, load, mode, 5, 11, 0);
    for _ in 0..25 {
        plain.step();
        let a = probed.decide_only();
        let b = probed.decide_only();
        assert_eq!(a, b, "decide_only is not idempotent");
        probed.step();
    }
    assert_eq!(snapshot(&plain), snapshot(&probed));
}

//! Mini property-testing framework (proptest is unavailable offline).
//!
//! A property runs over `cases` random inputs drawn from a deterministic
//! generator; on failure the framework *shrinks* the failing case by
//! retrying with each "simpler" variant the `Shrink` implementation offers
//! and reports the smallest reproduction found.

use repro::rng::Rng;

/// A random-input generator with shrinking.
pub trait Gen: Sized + std::fmt::Debug + Clone {
    /// Draw one case.
    fn generate(rng: &mut Rng) -> Self;
    /// Candidate simplifications, simplest first (empty = atomic).
    fn shrink(&self) -> Vec<Self> {
        Vec::new()
    }
}

/// Run `prop` over `cases` generated inputs; panics with the smallest
/// failing case found after shrinking.
pub fn check<G: Gen, F: Fn(&G) -> Result<(), String>>(name: &str, cases: u64, prop: F) {
    let mut rng = Rng::for_stream(0xC0FFEE, name.len() as u64);
    for case in 0..cases {
        let input = G::generate(&mut rng);
        if let Err(msg) = prop(&input) {
            // shrink loop: greedily take any simpler failing candidate
            let mut best = (input.clone(), msg.clone());
            let mut improved = true;
            let mut rounds = 0;
            while improved && rounds < 200 {
                improved = false;
                rounds += 1;
                for cand in best.0.shrink() {
                    if let Err(m) = prop(&cand) {
                        best = (cand, m);
                        improved = true;
                        break;
                    }
                }
            }
            panic!(
                "property {name:?} failed on case {case}:\n  input (shrunk): {:?}\n  error: {}",
                best.0, best.1
            );
        }
    }
}

/// Standard PDES test-case parameters.
#[derive(Clone, Debug)]
pub struct PdesCase {
    pub l: usize,
    pub nv: u64,
    pub delta: f64,
    pub rd: bool,
    pub steps: usize,
    pub seed: u64,
}

impl Gen for PdesCase {
    fn generate(rng: &mut Rng) -> Self {
        let ls = [3usize, 5, 8, 16, 33, 64, 100];
        let nvs = [1u64, 2, 3, 10, 100];
        let deltas = [0.0, 0.5, 1.0, 5.0, 20.0, f64::INFINITY];
        PdesCase {
            l: ls[rng.below(ls.len() as u64) as usize],
            nv: nvs[rng.below(nvs.len() as u64) as usize],
            delta: deltas[rng.below(deltas.len() as u64) as usize],
            rd: rng.uniform() < 0.25,
            steps: 1 + rng.below(120) as usize,
            seed: rng.next_u64(),
        }
    }

    fn shrink(&self) -> Vec<Self> {
        let mut out = Vec::new();
        if self.steps > 1 {
            out.push(PdesCase {
                steps: self.steps / 2,
                ..self.clone()
            });
        }
        if self.l > 3 {
            out.push(PdesCase {
                l: (self.l / 2).max(3),
                ..self.clone()
            });
        }
        if self.nv > 1 {
            out.push(PdesCase {
                nv: 1,
                ..self.clone()
            });
        }
        out
    }
}

impl PdesCase {
    /// The mode this case describes.
    pub fn mode(&self) -> repro::pdes::Mode {
        use repro::pdes::Mode;
        match (self.rd, self.delta.is_finite()) {
            (false, false) => Mode::Conservative,
            (false, true) => Mode::Windowed { delta: self.delta },
            (true, false) => Mode::Rd,
            (true, true) => Mode::WindowedRd { delta: self.delta },
        }
    }

    /// The volume load.
    pub fn load(&self) -> repro::pdes::VolumeLoad {
        repro::pdes::VolumeLoad::Sites(self.nv)
    }
}

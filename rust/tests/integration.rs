//! Integration tests across layers: artifact runtime ⇄ native substrate
//! cross-validation, campaign end-to-end smoke, experiment drivers.
//!
//! Artifact tests are skipped gracefully when `make artifacts` has not run
//! (e.g. a pure-cargo environment); CI always builds artifacts first.

use std::path::{Path, PathBuf};

use repro::coordinator::{run_artifact_ensemble, run_ensemble, JaxRunSpec, RunSpec};
use repro::pdes::{Mode, VolumeLoad};
use repro::runtime::PdesRuntime;
use repro::stats::Lane;

fn artifacts_dir() -> Option<PathBuf> {
    let dir = Path::new(env!("CARGO_MANIFEST_DIR")).join("artifacts");
    dir.join("manifest.txt").exists().then_some(dir)
}

#[test]
fn artifact_chunk_executes_and_chains() {
    let Some(dir) = artifacts_dir() else {
        eprintln!("skipped: no artifacts");
        return;
    };
    let mut rt = PdesRuntime::load(&dir).unwrap();
    let exe = rt.executor("pdes_L16_B4_T8").unwrap();
    let params = repro::runtime::pack_params(VolumeLoad::Sites(1), Mode::Conservative);
    let pend0 = vec![3i32; 4 * 16]; // N_V = 1: every event is two-sided
    let r1 = exe.run(&vec![0.0; 4 * 16], &pend0, [1, 2], params).unwrap();
    assert_eq!(r1.tau.len(), 64);
    assert_eq!(r1.pend.len(), 64);
    assert_eq!(r1.stats.len(), 8 * 4 * 11);
    // first step from a synchronized start: u == 1 on every row
    for row in 0..4 {
        assert_eq!(r1.stats_row(0, row)[0], 1.0);
    }
    // N_V = 1 events stay two-sided forever
    assert!(r1.pend.iter().all(|&p| p == 3));
    // chain: taus keep growing
    let r2 = exe.run(&r1.tau, &r1.pend, [3, 4], params).unwrap();
    for (a, b) in r1.tau.iter().zip(&r2.tau) {
        assert!(b >= a);
    }
    // monotone virtual time per row: mean lane is nondecreasing over steps
    for row in 0..4 {
        let mut prev = 0.0;
        for t in 0..8 {
            let mean = r1.stats_row(t, row)[1];
            assert!(mean >= prev);
            prev = mean;
        }
    }
}

#[test]
fn artifact_and_native_paths_agree_statistically() {
    let Some(dir) = artifacts_dir() else {
        eprintln!("skipped: no artifacts");
        return;
    };
    let mut rt = PdesRuntime::load(&dir).unwrap();
    for (mode, load) in [
        (Mode::Conservative, VolumeLoad::Sites(1)),
        (Mode::Windowed { delta: 5.0 }, VolumeLoad::Sites(1)),
        (Mode::Windowed { delta: 5.0 }, VolumeLoad::Sites(10)),
        (Mode::WindowedRd { delta: 5.0 }, VolumeLoad::Infinite),
    ] {
        let jax = run_artifact_ensemble(
            &mut rt,
            &JaxRunSpec {
                l: 64,
                load,
                mode,
                trials: 64,
                steps: 96,
                seed: 17,
            },
        )
        .unwrap();
        let native = run_ensemble(&RunSpec {
            l: 64,
            load,
            mode,
            trials: 64,
            steps: 96,
            seed: 18,
            streams: repro::pdes::StreamFamily::RowV1,
            control: repro::coordinator::Control::Static,
        });
        for lane in [Lane::U, Lane::W, Lane::Wa] {
            let a = jax.tail_mean(lane, 0.25);
            let b = native.tail_mean(lane, 0.25);
            let t_end = jax.steps() - 1;
            let noise = (jax.stderr(t_end, lane).powi(2) + native.stderr(t_end, lane).powi(2))
                .sqrt()
                .max(1e-6);
            assert!(
                (a - b).abs() < 6.0 * noise + 0.02 * b.abs().max(0.05),
                "{mode:?} {load:?} lane {lane:?}: jax {a} vs native {b} (noise {noise})"
            );
        }
    }
}

#[test]
fn experiment_drivers_smoke() {
    // quick-mode smoke of the cheap drivers (the full set runs in benches)
    let out = std::env::temp_dir().join("repro_it_results");
    let ctx = repro::experiments::Ctx::new(&out, true);
    for name in ["fig3", "fig7", "fig10"] {
        repro::experiments::run(name, &ctx).unwrap();
    }
    assert!(out.join("fig3_snapshots.tsv").exists());
    assert!(out.join("fig7_surfaces.tsv").exists());
    assert!(out.join("fig10_groups.tsv").exists());
    std::fs::remove_dir_all(&out).ok();
}

#[test]
fn steady_state_campaign_reproduces_u_inf_trend() {
    // u(L) must decrease toward ~0.2465 as L grows (finite-size from above)
    let mut last = 1.0;
    for l in [16usize, 64, 256] {
        let st = repro::coordinator::steady_state(
            &RunSpec {
                l,
                load: VolumeLoad::Sites(1),
                mode: Mode::Conservative,
                trials: 12,
                steps: 0,
                seed: 5,
                streams: repro::pdes::StreamFamily::RowV1,
                control: repro::coordinator::Control::Static,
            },
            1500,
            1500,
        );
        assert!(st.u < last + 0.005, "u should fall with L: {} at L={l}", st.u);
        assert!(st.u > 0.2, "u must stay finite");
        last = st.u;
    }
    assert!((0.24..0.30).contains(&last));
}

#[test]
fn window_bounds_width_at_scale() {
    // the paper's measurement-phase claim at L = 1000
    let st = repro::coordinator::steady_state(
        &RunSpec {
            l: 1000,
            load: VolumeLoad::Sites(10),
            mode: Mode::Windowed { delta: 5.0 },
            trials: 6,
            steps: 0,
            seed: 6,
            streams: repro::pdes::StreamFamily::RowV1,
            control: repro::coordinator::Control::Static,
        },
        1000,
        1000,
    );
    assert!(st.wa < 5.0, "w_a = {} must stay below Δ", st.wa);
    assert!(st.u > 0.05, "utilization must stay finite");
}

#[test]
fn shard_merge_determinism_on_fixed_campaign() {
    // coordinator/pool.rs contract: map_shards_with produces identical
    // ensemble moments for worker counts 1, 2 and 7 on a fixed campaign
    // (per-trial streams are scheduling-independent; only floating-point
    // merge order may differ, bounded here at 1e-12)
    use repro::coordinator::pool::map_shards_with;
    use repro::pdes::{BatchPdes, Topology};
    use repro::stats::EnsembleSeries;

    let (l, trials, steps, seed) = (24usize, 14u64, 25usize, 31u64);
    let run = |workers: usize| {
        map_shards_with(
            trials,
            workers,
            |range| {
                let mut series = EnsembleSeries::new(steps);
                let rows = (range.end - range.start) as usize;
                let mut sim = BatchPdes::with_streams(
                    Topology::Ring { l },
                    VolumeLoad::Sites(1),
                    Mode::Windowed { delta: 4.0 },
                    rows,
                    seed,
                    range.start,
                );
                for t in 0..steps {
                    sim.step();
                    series.push_batch_rows(t, sim.tau(), sim.pes(), sim.counts());
                }
                series
            },
            |mut a, b| {
                a.merge(&b);
                a
            },
        )
        .unwrap()
    };
    let one = run(1);
    assert_eq!(one.trials(), trials);
    for workers in [2usize, 7] {
        let other = run(workers);
        assert_eq!(other.trials(), trials);
        for lane in [Lane::U, Lane::W2, Lane::Min, Lane::Max, Lane::W] {
            for t in [0usize, steps / 2, steps - 1] {
                let (a, b) = (one.mean(t, lane), other.mean(t, lane));
                assert!(
                    (a - b).abs() < 1e-12,
                    "workers {workers}, {lane:?}, t={t}: {a} vs {b}"
                );
                let (ea, eb) = (one.stderr(t, lane), other.stderr(t, lane));
                assert!(
                    (ea - eb).abs() < 1e-12,
                    "workers {workers}, {lane:?}, t={t}: stderr {ea} vs {eb}"
                );
            }
        }
    }
}

#[test]
fn topology_experiment_driver_smoke() {
    let out = std::env::temp_dir().join("repro_it_topology");
    let ctx = repro::experiments::Ctx::new(&out, true);
    repro::experiments::run("topology", &ctx).unwrap();
    assert!(out.join("topology_sweep.tsv").exists());
    std::fs::remove_dir_all(&out).ok();
}

#[test]
fn cli_binary_parses_and_reports_info() {
    // exercise the Args path exactly as main() does
    let args = repro::cli::Args::parse(
        ["run", "--l", "32", "--nv", "inf", "--delta", "inf", "--rd"]
            .iter()
            .map(|s| s.to_string()),
    )
    .unwrap();
    assert_eq!(args.command, "run");
    assert!(args.has_flag("rd"));
    assert_eq!(args.opt("nv", ""), "inf");
}

//! Integration tests of the declarative campaign layer: worker-count
//! invariance of every figure TSV, kill/resume byte-identity, pinned
//! cache keys, and the EXPERIMENTS.md drift gate.
//!
//! The determinism contract under test (see `coordinator::plan`): every
//! sweep point is executed with the canonical serial trial fold, so TSV
//! outputs depend only on the plan — never on `--workers`, never on which
//! points were restored from the cache.

use std::fs;
use std::path::{Path, PathBuf};

use repro::coordinator::{
    run_plan, Backoff, CampaignOpts, CancelToken, FaultPlan, Profile, SweepPlan,
};
use repro::experiments::{self, Ctx};
use repro::DEFAULT_SEED;

/// All TSV files under `dir` (not the cache), sorted by name.
fn tsv_files(dir: &Path) -> Vec<(String, String)> {
    let mut out = Vec::new();
    for entry in fs::read_dir(dir).unwrap() {
        let entry = entry.unwrap();
        let name = entry.file_name().to_string_lossy().to_string();
        if name.ends_with(".tsv") {
            out.push((name, fs::read_to_string(entry.path()).unwrap()));
        }
    }
    out.sort();
    assert!(!out.is_empty(), "no TSV output under {}", dir.display());
    out
}

/// Run one figure driver quick into a fresh directory with the given
/// point-level worker count; return its TSV bytes.
fn run_quick(name: &str, workers: usize, tag: &str) -> Vec<(String, String)> {
    let out = std::env::temp_dir().join(format!("repro_cplan_{name}_{tag}"));
    fs::remove_dir_all(&out).ok();
    let mut ctx = Ctx::new(&out, true);
    ctx.workers = workers;
    experiments::run(name, &ctx).unwrap();
    let files = tsv_files(&out);
    fs::remove_dir_all(&out).ok();
    files
}

#[test]
fn figure_tsv_bytes_are_worker_invariant() {
    // one run_ensemble-style figure, one steady_state-style figure, one
    // topology sweep — the three execution shapes of the paper's grids —
    // plus the two model-payload experiments (the acceptance criterion
    // of the payload PR: `repro ising --quick` / `repro updatestats
    // --quick` byte-identical across --workers)
    for name in ["fig2", "fig9", "topology", "ising", "updatestats"] {
        let one = run_quick(name, 1, "w1");
        let four = run_quick(name, 4, "w4");
        assert_eq!(
            one.len(),
            four.len(),
            "{name}: file sets differ between worker counts"
        );
        for ((n1, b1), (n4, b4)) in one.iter().zip(&four) {
            assert_eq!(n1, n4, "{name}: file name drift");
            assert_eq!(b1, b4, "{name}/{n1}: bytes differ between workers 1 and 4");
        }
    }
}

#[test]
fn kill_and_resume_reproduces_bytes_and_skips_completed_points() {
    let profile = Profile::quick(DEFAULT_SEED);
    let full_plan = experiments::plan_for("fig2", &profile).unwrap();

    // reference: one uninterrupted quick run
    let reference = run_quick("fig2", 2, "ref");

    // "killed" run: execute only the first half of the plan, then drop
    // the scheduler with the cache directory left behind
    let out = std::env::temp_dir().join("repro_cplan_fig2_resume");
    fs::remove_dir_all(&out).ok();
    let cache_dir: PathBuf = out.join(".cache");
    let mut half = SweepPlan::new("fig2", "first half (simulated kill)");
    for p in &full_plan.points[..full_plan.len() / 2] {
        half.push(p.clone());
    }
    let opts = CampaignOpts {
        workers: 2,
        cache_dir: Some(cache_dir.clone()),
        ..Default::default()
    };
    let (_, rep) = run_plan(&half, &opts).unwrap();
    assert_eq!(rep.executed, half.len());

    // resume: the full driver against the same output directory must
    // restore the completed half from the cache...
    let mut ctx = Ctx::new(&out, true);
    ctx.workers = 2;
    ctx.resume = true;
    experiments::run("fig2", &ctx).unwrap();
    // ...and produce byte-identical TSVs
    let resumed = tsv_files(&out);
    assert_eq!(reference, resumed, "resumed TSVs differ from an uninterrupted run");

    // a second resume pass re-executes nothing at all
    let (_, rep) = run_plan(
        &full_plan,
        &CampaignOpts {
            workers: 2,
            resume: true,
            cache_dir: Some(cache_dir),
            ..Default::default()
        },
    )
    .unwrap();
    assert_eq!(rep.executed, 0, "warm cache must satisfy every point");
    assert_eq!(rep.cache_hits, full_plan.len());
    fs::remove_dir_all(&out).ok();
}

#[test]
fn cache_keys_are_pinned() {
    // frozen v1 identities: these exact spec strings and FNV-1a keys are
    // the on-disk resume protocol — a change here invalidates every
    // existing cache and must be deliberate (bump the spec version)
    let plan = experiments::plan_for("fig2", &Profile::quick(DEFAULT_SEED)).unwrap();
    assert_eq!(
        plan.points[0].spec(),
        "repro/v1 topo=ring:10 run=l=10;load=1;mode=cons;trials=32;steps=100;seed=20020601 samp=curves:100"
    );
    assert_eq!(plan.points[0].key(), 0x82e3a9d57c768ed5);

    let plan = experiments::plan_for("topology", &Profile::quick(DEFAULT_SEED)).unwrap();
    assert_eq!(
        plan.points[0].spec(),
        "repro/v1 topo=ring:64 run=l=64;load=1;mode=win:1;trials=4;steps=0;seed=20020601 samp=steady:300:300"
    );
    assert_eq!(plan.points[0].key(), 0x576df342a203e67c);

    // model-payload points: the spec grows a trailing model= field (the
    // keys were cross-computed with the independent Python FNV-1a)
    let plan = experiments::plan_for("ising", &Profile::quick(DEFAULT_SEED)).unwrap();
    assert_eq!(
        plan.points[0].spec(),
        "repro/v1 topo=ring:64 run=l=64;load=1;mode=win:1;trials=4;steps=0;seed=20020601 samp=modelsteady:200:400 model=ising:0.7:1"
    );
    assert_eq!(plan.points[0].key(), 0xc7db958b97a37ad3);

    let plan = experiments::plan_for("updatestats", &Profile::quick(DEFAULT_SEED)).unwrap();
    assert_eq!(
        plan.points[0].spec(),
        "repro/v1 topo=ring:64 run=l=64;load=1;mode=cons;trials=4;steps=0;seed=20020601 samp=updstats:200:400 model=sitecounter"
    );
    assert_eq!(plan.points[0].key(), 0x68ad75a80eaf385b);
}

#[test]
fn corrupt_cache_entries_recompute_under_resume_with_correct_bytes() {
    // the ResultCache hardening, end to end: bit-flip one cached entry
    // and truncate another, then --resume — the damaged points must be
    // recomputed (not error out, not serve wrong data) and the final
    // TSVs must equal an uninterrupted run byte for byte
    let reference = run_quick("ising", 2, "corrupt_ref");

    let out = std::env::temp_dir().join("repro_cplan_corrupt_resume");
    fs::remove_dir_all(&out).ok();
    let mut ctx = Ctx::new(&out, true);
    ctx.workers = 2;
    experiments::run("ising", &ctx).unwrap();

    let cache_dir: PathBuf = out.join(".cache");
    let mut entries: Vec<PathBuf> = fs::read_dir(&cache_dir)
        .unwrap()
        .filter_map(|e| e.ok())
        .map(|e| e.path())
        .filter(|p| p.extension().is_some_and(|x| x == "point"))
        .collect();
    entries.sort();
    assert!(entries.len() >= 2, "expected cached points, got {entries:?}");

    // bit-flip one byte inside the first entry's payload region
    let mut bytes = fs::read(&entries[0]).unwrap();
    let flip_at = bytes.len() - 9;
    bytes[flip_at] = if bytes[flip_at] == b'0' { b'1' } else { b'0' };
    fs::write(&entries[0], &bytes).unwrap();
    // truncate the second entry mid-payload
    let bytes = fs::read(&entries[1]).unwrap();
    fs::write(&entries[1], &bytes[..bytes.len() / 2]).unwrap();

    let mut ctx = Ctx::new(&out, true);
    ctx.workers = 2;
    ctx.resume = true;
    experiments::run("ising", &ctx).unwrap();
    let resumed = tsv_files(&out);
    assert_eq!(
        reference, resumed,
        "TSVs after corrupt-entry resume differ from an uninterrupted run"
    );

    // the damaged entries were re-stored: a further resume is all-cache
    let plan = experiments::plan_for("ising", &Profile::quick(DEFAULT_SEED)).unwrap();
    let (_, rep) = run_plan(
        &plan,
        &CampaignOpts {
            workers: 2,
            resume: true,
            cache_dir: Some(cache_dir),
            ..Default::default()
        },
    )
    .unwrap();
    assert_eq!(rep.executed, 0, "repaired cache must satisfy every point");
    fs::remove_dir_all(&out).ok();
}

#[test]
fn sigterm_simulated_drain_flushes_cache_and_resumes_bitwise() {
    // the SIGTERM story end-to-end through the figure driver, with the
    // deterministic poll-counted token standing in for the signal (the
    // real handler sets the same flag the token observes): cancel
    // mid-plan, assert completed points were flushed to the cache, then
    // resume and require byte-identical TSVs with executed == 0 for the
    // previously completed points
    let reference = run_quick("fig2", 1, "drain_ref");

    let out = std::env::temp_dir().join("repro_cplan_fig2_drain");
    fs::remove_dir_all(&out).ok();
    let mut ctx = Ctx::new(&out, true);
    ctx.workers = 1;
    // trips partway through the plan's serial execution
    ctx.cancel = Some(CancelToken::after_checks(500));
    let err = experiments::run("fig2", &ctx)
        .expect_err("a drained campaign must surface as an error")
        .to_string();
    assert!(err.contains("--resume"), "unexpected drain error: {err}");

    // the drain left rename-published entries behind: the cache holds
    // only complete points, never partial state
    let cache_dir: PathBuf = out.join(".cache");
    let flushed = fs::read_dir(&cache_dir)
        .expect("cache dir must exist after a drain")
        .filter_map(|e| e.ok())
        .filter(|e| e.path().extension().is_some_and(|x| x == "point"))
        .count();
    let full_plan = experiments::plan_for("fig2", &Profile::quick(DEFAULT_SEED)).unwrap();
    assert!(
        flushed >= 1 && flushed < full_plan.len(),
        "expected a partial flush, got {flushed}/{} entries",
        full_plan.len()
    );

    // resume: completed points are cache hits (executed only the rest),
    // and the final TSVs are byte-identical to an uninterrupted run
    let mut ctx = Ctx::new(&out, true);
    ctx.workers = 1;
    ctx.resume = true;
    experiments::run("fig2", &ctx).unwrap();
    assert_eq!(reference, tsv_files(&out), "drained+resumed TSVs differ");

    // and a further resume executes nothing at all
    let (_, rep) = run_plan(
        &full_plan,
        &CampaignOpts {
            resume: true,
            cache_dir: Some(cache_dir),
            ..Default::default()
        },
    )
    .unwrap();
    assert_eq!(rep.executed, 0, "warm cache must satisfy every point");
    fs::remove_dir_all(&out).ok();
}

#[test]
fn sharded_pool_worker_panic_under_campaign_is_isolated() {
    // stress the panic-unwinds-while-StepPools-are-live hazard under the
    // campaign supervisor (rides the `sharded pool pe_family` TSan
    // filter): every point advances on lattice-sharded engines
    // (persistent worker pools), a sibling point panics mid-campaign,
    // and the supervisor must retry it without perturbing the pools'
    // trajectories — bitwise-stable output across repetitions
    let profile = Profile::quick(DEFAULT_SEED);
    let full_plan = experiments::plan_for("fig2", &profile).unwrap();
    let mut plan = SweepPlan::new("fig2", "pool-panic stress slice");
    for p in &full_plan.points[..4.min(full_plan.len())] {
        plan.push(p.clone());
    }
    let target = plan.points[1].spec();
    let baseline = run_plan(
        &plan,
        &CampaignOpts {
            workers: 2,
            lattice_workers: 2,
            quiet: true,
            ..Default::default()
        },
    )
    .unwrap()
    .0
    .iter()
    .map(|r| r.to_cache_text())
    .collect::<Vec<_>>();
    for round in 0..3 {
        let opts = CampaignOpts {
            workers: 2,
            lattice_workers: 2,
            max_retries: 2,
            backoff: Backoff::none(),
            faults: Some(FaultPlan::new().panic_on(target.clone(), 1)),
            quiet: true,
            ..Default::default()
        };
        let (results, report) = run_plan(&plan, &opts).unwrap();
        assert_eq!(report.retried, 1, "round {round}: injected panic retried");
        assert!(report.quarantined.is_empty());
        let texts: Vec<String> = results.iter().map(|r| r.to_cache_text()).collect();
        assert_eq!(
            texts, baseline,
            "round {round}: pool trajectories perturbed by a sibling panic"
        );
    }
}

#[test]
fn shared_grids_share_cache_entries_across_figures() {
    // content addressing: fig6's Δ = ∞ column and fig11's x-axis measure
    // the same conservative u_∞ cells, so their specs must collide ON
    // PURPOSE (under --resume one computation serves both figures)
    let p = Profile::quick(DEFAULT_SEED);
    let fig6 = experiments::plan_for("fig6", &p).unwrap();
    let fig11 = experiments::plan_for("fig11", &p).unwrap();
    let fig6_specs: std::collections::BTreeSet<String> =
        fig6.points.iter().map(|pt| pt.spec()).collect();
    let shared = fig11
        .points
        .iter()
        .filter(|pt| fig6_specs.contains(&pt.spec()))
        .count();
    assert!(
        shared >= 9,
        "expected the conservative u_inf L-grids to be shared, got {shared}"
    );
}

#[test]
fn experiments_md_matches_committed_file() {
    let root = Path::new(env!("CARGO_MANIFEST_DIR"))
        .parent()
        .expect("workspace root");
    let committed = fs::read_to_string(root.join("EXPERIMENTS.md"))
        .expect("EXPERIMENTS.md must exist at the workspace root");
    let generated = repro::experiments::experiments_md();
    if committed != generated {
        for (i, (a, b)) in committed.lines().zip(generated.lines()).enumerate() {
            assert_eq!(
                a,
                b,
                "EXPERIMENTS.md line {} drifted from the plan definitions — \
                 regenerate with `python3 python/tools/gen_experiments_md.py`",
                i + 1
            );
        }
        panic!(
            "EXPERIMENTS.md length drifted ({} vs {} bytes) — regenerate with \
             `python3 python/tools/gen_experiments_md.py`",
            committed.len(),
            generated.len()
        );
    }
}

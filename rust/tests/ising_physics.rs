//! Physics-invariance property test for the kinetic Ising payload: the
//! Δ-window changes *scheduling*, never *physics*.
//!
//! The asynchronous Glauber chain driven by the conservative scheduler
//! samples the 1-d equilibrium Ising distribution, whose exact energy
//! per spin is e = −J·tanh(βJ).  The time-averaged energy must match it
//! for the unconstrained scheme AND for every window width — Δ only
//! reorders which PEs work when, and the update sequence each spin sees
//! remains a faithful asynchronous Glauber dynamics (each event is a
//! flip attempt at the event's virtual time against causally-frozen
//! neighbours, Eq. 1).  This is the validation claim the old
//! `examples/ising_chain.rs` printed but nothing enforced; it is now a
//! `cargo test` gate.
//!
//! ## Tolerance rationale (documented, deliberately conservative)
//!
//! The estimator averages the energy over `MEASURE` = 4000 steps × 2
//! replica rows at L = 128 after a 1000-step warm-up.  Consecutive
//! steps are correlated (the Glauber autocorrelation time at βJ = 0.7
//! is a few sweeps; one parallel step updates ~u·L ≈ 0.25·L spins), so
//! the effective sample count is ~u·MEASURE·ROWS/τ_corr ≳ 10³, giving a
//! statistical error σ ≈ sqrt(2/(3·L))/sqrt(N_eff) ≈ 2–4 × 10⁻³.  The
//! gate is |ē − e_exact| < 0.02 — about 5σ — so the fixed-seed values
//! (cross-computed by the Python port in
//! `python/tools/crosscheck_sharded.py --physics`, which replays these
//! exact streams) sit comfortably inside, while any real defect (a
//! wrong flip probability, a causality leak, a Δ-dependent bias) moves
//! the mean by ≳ 0.05 and fails loudly.  The test is deterministic: it
//! either always passes or always fails on a given build.

use repro::pdes::{BatchPdes, Ising1d, Mode, Model, ModelSpec, Topology, VolumeLoad};

const L: usize = 128;
const ROWS: usize = 2;
const SEED: u64 = 4242;
const BETA: f64 = 0.7;
const COUPLING: f64 = 1.0;
const WARM: usize = 1000;
const MEASURE: usize = 4000;
const TOLERANCE: f64 = 0.02;

/// Time-averaged Ising energy per spin under one scheduler mode,
/// replaying the exact streams the Python cross-check pins.
fn measured_energy(mode: Mode) -> f64 {
    let topo = Topology::Ring { l: L };
    let nbr = topo.neighbour_table();
    let mut sim = BatchPdes::with_streams(topo, VolumeLoad::Sites(1), mode, ROWS, SEED, 0);
    sim.attach_models(
        ModelSpec::Ising {
            beta: BETA,
            coupling: COUPLING,
        }
        .build_rows(L, ROWS),
    );
    for _ in 0..WARM {
        sim.step();
    }
    let mut acc = 0.0;
    for _ in 0..MEASURE {
        sim.step();
        for row in 0..ROWS {
            acc += sim.model_row(row).unwrap().observe(&nbr).unwrap().energy;
        }
    }
    acc / (MEASURE as f64 * ROWS as f64)
}

#[test]
fn ising_energy_matches_exact_for_every_window_width() {
    let exact = Ising1d::exact_ring_energy(BETA, COUPLING);
    for (tag, mode) in [
        ("conservative", Mode::Conservative),
        ("windowed_d1", Mode::Windowed { delta: 1.0 }),
        ("windowed_d10", Mode::Windowed { delta: 10.0 }),
        ("windowed_d100", Mode::Windowed { delta: 100.0 }),
    ] {
        let e = measured_energy(mode);
        assert!(
            (e - exact).abs() < TOLERANCE,
            "{tag}: <e> = {e:.5} vs exact {exact:.5} (|diff| = {:.5} >= {TOLERANCE}) — \
             the window must change scheduling, not physics",
            (e - exact).abs()
        );
    }
}

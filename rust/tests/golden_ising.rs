//! Golden Ising-payload trajectories: pinned spin checksums, integer
//! bond sums, update counts and full-precision τ rows at steps
//! {1, 16, 256} for two fixed payload configurations, committed in
//! `tests/fixtures/golden_ising.txt`.
//!
//! Purpose (mirror of `golden_trajectory.rs` for the payload layer): the
//! batched and sharded engines are asserted equal *to each other* with
//! payloads attached by the determinism suite, but a refactor changing
//! both in lockstep — a moved `apply_event` call site, a reordered model
//! draw, a changed flip rule — would slip through a relative check.
//! The fixture anchors the payload trajectory family itself.  Values
//! come from the independent Python port
//! (`python/tools/crosscheck_sharded.py --fixture`).
//!
//! Tolerances: τ is pinned at 1e-9 relative (ziggurat exponentials route
//! through libm, same rationale as `golden_trajectory.rs`).  The spin
//! lanes (FNV-1a over the ±1 bytes, integer bond sum) are compared
//! exactly — the Glauber accept draw `u < 1/(1+exp(βΔE))` crosses a
//! libm-jitter boundary with probability ~2⁻⁵² per event, negligible
//! over the fixture's ≲10⁴ events; if a platform ever trips it, the
//! failure is a deliberate signal to regenerate, not noise to widen.

use repro::pdes::{BatchPdes, Ising1d, Mode, Model, ModelSpec, ShardedPdes, Topology, VolumeLoad};

const FIXTURE: &str = include_str!("fixtures/golden_ising.txt");
const SAMPLED_STEPS: [u64; 3] = [1, 16, 256];

/// FNV-1a over the spin bytes (±1 as two's-complement u8), mirroring the
/// generator.
fn fnv1a_spins(spins: &[i8]) -> u64 {
    let mut h: u64 = 0xCBF2_9CE4_8422_2325;
    for &s in spins {
        h ^= (s as u8) as u64;
        h = h.wrapping_mul(0x0000_0100_0000_01B3);
    }
    h
}

struct GoldenRow {
    step: u64,
    row: usize,
    spin_fnv: u64,
    bond_sum: i64,
    n_updated: u32,
    tau: Vec<f64>,
}

fn parse_fixture(tag: &str) -> Vec<GoldenRow> {
    let mut out = Vec::new();
    for line in FIXTURE.lines() {
        let line = line.trim();
        if line.is_empty() || line.starts_with('#') {
            continue;
        }
        let mut fields = line.split_whitespace();
        if fields.next() != Some(tag) {
            continue;
        }
        let step: u64 = fields.next().unwrap().parse().unwrap();
        let row: usize = fields.next().unwrap().parse().unwrap();
        let spin_fnv = u64::from_str_radix(fields.next().unwrap(), 16).unwrap();
        let bond_sum: i64 = fields.next().unwrap().parse().unwrap();
        let n_updated: u32 = fields.next().unwrap().parse().unwrap();
        let tau: Vec<f64> = fields.map(|f| f.parse().unwrap()).collect();
        out.push(GoldenRow {
            step,
            row,
            spin_fnv,
            bond_sum,
            n_updated,
            tau,
        });
    }
    assert!(
        !out.is_empty(),
        "no fixture rows for tag {tag:?} — regenerate with \
         python3 python/tools/crosscheck_sharded.py --fixture"
    );
    out
}

fn check_config(tag: &str, topology: Topology, mode: Mode, model: ModelSpec, rows: usize, seed: u64) {
    let golden = parse_fixture(tag);
    let nbr = topology.neighbour_table();
    let mut batch = BatchPdes::with_streams(topology, VolumeLoad::Sites(1), mode, rows, seed, 0);
    batch.attach_models(model.build_rows(topology.len(), rows));
    // worker count chosen to exercise real multi-block plans on L = 12
    let mut sharded =
        ShardedPdes::with_streams(topology, VolumeLoad::Sites(1), mode, rows, seed, 0, 3);
    sharded.attach_models(model.build_rows(topology.len(), rows));
    let spins_of = |sim: &BatchPdes, row: usize| -> Vec<i8> {
        sim.model_row(row)
            .unwrap()
            .as_any()
            .downcast_ref::<Ising1d>()
            .unwrap()
            .spins()
            .to_vec()
    };
    let mut done = 0u64;
    for &target in &SAMPLED_STEPS {
        while done < target {
            batch.step();
            sharded.step();
            done += 1;
        }
        // sharded ≡ batch with the payload attached: in-process, exact
        for row in 0..rows {
            for (k, (a, b)) in batch.tau_row(row).iter().zip(sharded.tau_row(row)).enumerate() {
                assert_eq!(
                    a.to_bits(),
                    b.to_bits(),
                    "{tag} step {target} row {row} PE {k}: sharded diverged from batch"
                );
            }
            assert_eq!(
                spins_of(&batch, row),
                spins_of(&sharded, row),
                "{tag} step {target} row {row}: payload state diverged across engines"
            );
        }
        // batch vs the committed golden values
        for g in golden.iter().filter(|g| g.step == target) {
            let spins = spins_of(&batch, g.row);
            assert_eq!(
                fnv1a_spins(&spins),
                g.spin_fnv,
                "{tag} step {target} row {}: spin checksum",
                g.row
            );
            let ising = batch
                .model_row(g.row)
                .unwrap()
                .as_any()
                .downcast_ref::<Ising1d>()
                .unwrap();
            assert_eq!(
                ising.bond_sum(&nbr),
                g.bond_sum,
                "{tag} step {target} row {}: bond sum",
                g.row
            );
            assert_eq!(
                batch.counts()[g.row],
                g.n_updated,
                "{tag} step {target} row {}: update count",
                g.row
            );
            let tau = batch.tau_row(g.row);
            assert_eq!(tau.len(), g.tau.len(), "{tag}: fixture row length");
            for (k, (&got, &want)) in tau.iter().zip(&g.tau).enumerate() {
                let tol = 1e-9 * want.abs().max(1e-12);
                assert!(
                    (got - want).abs() <= tol,
                    "{tag} step {target} row {} PE {k}: {got:e} != golden {want:e}",
                    g.row
                );
            }
        }
    }
    for &target in &SAMPLED_STEPS {
        assert_eq!(
            golden.iter().filter(|g| g.step == target).count(),
            rows,
            "{tag}: fixture misses step {target}"
        );
    }
}

#[test]
fn golden_ising_ring_windowed() {
    check_config(
        "ising_ring12_win2_b0.7",
        Topology::Ring { l: 12 },
        Mode::Windowed { delta: 2.0 },
        ModelSpec::Ising { beta: 0.7, coupling: 1.0 },
        2,
        20020601,
    );
}

#[test]
fn golden_ising_kring_conservative() {
    check_config(
        "ising_kring12_2_cons_b0.4",
        Topology::KRing { l: 12, k: 2 },
        Mode::Conservative,
        ModelSpec::Ising { beta: 0.4, coupling: 1.0 },
        1,
        7,
    );
}

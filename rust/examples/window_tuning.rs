//! Window tuning — the Discussion-section use case: for a fixed volume
//! load N_V, sweep the window width Δ and locate the efficiency knee
//! where utilization is near its unconstrained ceiling while the width
//! (memory bound) is still small.
//!
//! Ported onto the declarative campaign layer: the sweep is a
//! [`SweepPlan`] (one steady point per Δ plus the unconstrained
//! ceiling), executed by the generic scheduler — point-level fan-out
//! across the worker pool for free, byte-identical results for every
//! pool size.
//!
//! Run with: `cargo run --release --example window_tuning [--quick] [NV]`

use repro::coordinator::{run_plan, CampaignOpts, RunSpec, SweepPlan, SweepPoint};
use repro::pdes::{Mode, Topology, VolumeLoad};

fn main() -> anyhow::Result<()> {
    let args: Vec<String> = std::env::args().skip(1).collect();
    let quick = args.iter().any(|a| a == "--quick");
    let nv: u64 = args
        .iter()
        .find(|a| !a.starts_with("--"))
        .and_then(|s| s.parse().ok())
        .unwrap_or(100);
    let (l, trials, warm) = if quick { (64usize, 8u64, 300usize) } else { (256, 32, 2000) };
    let deltas: &[f64] = if quick {
        &[1.0, 5.0, 20.0, 100.0]
    } else {
        &[0.5, 1.0, 2.0, 5.0, 10.0, 20.0, 50.0, 100.0, 200.0]
    };

    // the sweep as data: ceiling first, then one point per Δ
    let mut plan = SweepPlan::new("window_tuning", "Δ-window tuning sweep");
    let run_spec = |mode| RunSpec {
        l,
        load: VolumeLoad::Sites(nv),
        mode,
        trials,
        steps: 0,
        seed: 11,
        streams: repro::pdes::StreamFamily::Pe,
        control: repro::coordinator::Control::Static,
    };
    plan.push(SweepPoint::steady(
        "ceiling",
        Topology::Ring { l },
        run_spec(Mode::Conservative),
        warm,
        warm,
    ));
    for &delta in deltas {
        plan.push(SweepPoint::steady(
            format!("d{delta}"),
            Topology::Ring { l },
            run_spec(Mode::Windowed { delta }),
            warm,
            warm,
        ));
    }
    let (results, _report) = run_plan(
        &plan,
        &CampaignOpts {
            quiet: true,
            ..Default::default()
        },
    )?;

    println!("Δ-window tuning at L = {l}, N_V = {nv} ({trials} trials, {warm}+{warm} steps)\n");
    println!(
        "{:>8} {:>8} {:>8} {:>8} {:>12}",
        "delta", "<u>", "<w>", "<w_a>", "u/w (knee)"
    );
    let ceiling = results[0].steady();
    let mut best = (0.0f64, 0.0f64); // (score, delta)
    for (&delta, result) in deltas.iter().zip(&results[1..]) {
        let st = result.steady();
        // efficiency score: progress per unit memory bound
        let score = st.u / st.w.max(1e-9);
        if score > best.0 {
            best = (score, delta);
        }
        println!(
            "{delta:>8} {:>8.3} {:>8.3} {:>8.3} {:>12.3}",
            st.u, st.w, st.wa, score
        );
    }
    println!(
        "\nunconstrained ceiling: <u> = {:.3}, <w> = {:.3} (diverges with L)",
        ceiling.u, ceiling.w
    );
    println!(
        "knee of u/w at Δ ≈ {} — \"the width of the Δ-window can serve as a tuning\n\
         parameter ... to optimize the utilization so as to maximize the efficiency\"",
        best.1
    );
    Ok(())
}

//! Window tuning — the Discussion-section use case: for a fixed volume
//! load N_V, sweep the window width Δ and locate the efficiency knee where
//! utilization is near its unconstrained ceiling while the width (memory
//! bound) is still small.
//!
//! Run with: `cargo run --release --example window_tuning [NV]`

use repro::coordinator::{steady_state, RunSpec};
use repro::pdes::{Mode, VolumeLoad};

fn main() {
    let nv: u64 = std::env::args()
        .nth(1)
        .and_then(|s| s.parse().ok())
        .unwrap_or(100);
    let l = 256;
    let deltas = [0.5, 1.0, 2.0, 5.0, 10.0, 20.0, 50.0, 100.0, 200.0];

    println!("Δ-window tuning at L = {l}, N_V = {nv} (32 trials, 2000+2000 steps)\n");
    println!(
        "{:>8} {:>8} {:>8} {:>8} {:>12}",
        "delta", "<u>", "<w>", "<w_a>", "u/w (knee)"
    );

    // unconstrained ceiling for reference
    let ceiling = steady_state(
        &RunSpec {
            l,
            load: VolumeLoad::Sites(nv),
            mode: Mode::Conservative,
            trials: 32,
            steps: 0,
            seed: 11,
        },
        2000,
        2000,
    );

    let mut best = (0.0f64, 0.0f64); // (score, delta)
    for delta in deltas {
        let st = steady_state(
            &RunSpec {
                l,
                load: VolumeLoad::Sites(nv),
                mode: Mode::Windowed { delta },
                trials: 32,
                steps: 0,
                seed: 11,
            },
            2000,
            2000,
        );
        // efficiency score: progress per unit memory bound
        let score = st.u / st.w.max(1e-9);
        if score > best.0 {
            best = (score, delta);
        }
        println!(
            "{delta:>8} {:>8.3} {:>8.3} {:>8.3} {:>12.3}",
            st.u, st.w, st.wa, score
        );
    }
    println!(
        "\nunconstrained ceiling: <u> = {:.3}, <w> = {:.3} (diverges with L)",
        ceiling.u, ceiling.w
    );
    println!(
        "knee of u/w at Δ ≈ {} — \"the width of the Δ-window can serve as a tuning\n\
         parameter ... to optimize the utilization so as to maximize the efficiency\"",
        best.1
    );
}

//! End-to-end full-stack driver (the DESIGN.md §5 validation run):
//!
//! 1. loads the AOT-compiled JAX/Pallas artifacts through the PJRT runtime
//!    (L1 Pallas kernel + L2 scan, Python nowhere on the path),
//! 2. streams chunked ensembles through the Rust coordinator for every
//!    artifact ring size, constrained and unconstrained,
//! 3. cross-validates the artifact-path statistics against the native
//!    substrate (same model, independent implementation + RNG),
//! 4. extrapolates ⟨u_∞⟩ over the artifact L-grid and reports the paper's
//!    headline result: finite utilization AND bounded width under the
//!    Δ-window.
//!
//! Run with: `cargo run --release --example e2e_campaign` (after
//! `make artifacts`).  The run is recorded in EXPERIMENTS.md §E2E.

use std::path::Path;
use std::time::Instant;

use repro::coordinator::{run_artifact_ensemble, run_ensemble, JaxRunSpec, RunSpec};
use repro::fit::extrapolate_to_zero;
use repro::pdes::{Mode, VolumeLoad};
use repro::runtime::PdesRuntime;
use repro::stats::Lane;

fn main() -> anyhow::Result<()> {
    let dir = Path::new("artifacts");
    // graceful skip keeps `cargo run --example e2e_campaign` green in
    // checkouts without compiled artifacts (the CI examples job, fresh
    // clones) — the run is only meaningful after `make artifacts`
    if !dir.join("manifest.txt").exists() {
        println!(
            "artifacts/manifest.txt not found — skipping the artifact cross-validation \
             (run `make artifacts` first, then re-run this example)"
        );
        return Ok(());
    }
    let quick = std::env::args().any(|a| a == "--quick");
    let mut rt = PdesRuntime::load(dir)?;
    println!("PJRT platform: {}\n", rt.platform());

    let delta = 10.0;
    let steps = if quick { 64 } else { 256 };
    let trials = if quick { 8 } else { 32 };
    let mut xs = Vec::new();
    let mut us = Vec::new();

    println!(
        "{:>6} {:>8} {:>22} {:>10} {:>10} {:>10} {:>10}",
        "L", "path", "mode", "<u>", "<w_a>", "dev(u)", "steps/s"
    );

    for l in [16usize, 64, 256, 1024] {
        for (mode, tag) in [
            (Mode::Conservative, "unconstrained"),
            (Mode::Windowed { delta }, "Δ-window (Δ=10)"),
        ] {
            // --- artifact path (L1+L2 through PJRT)
            let spec = JaxRunSpec {
                l,
                load: VolumeLoad::Sites(1),
                mode,
                trials,
                steps,
                seed: 42,
            };
            let t0 = Instant::now();
            let jax = run_artifact_ensemble(&mut rt, &spec)?;
            let jax_secs = t0.elapsed().as_secs_f64();
            let t_end = jax.steps() - 1;
            let u_jax = jax.tail_mean(Lane::U, 0.25);
            let wa_jax = jax.mean(t_end, Lane::Wa);

            // --- native path (L3 substrate), same statistics pipeline
            let native = run_ensemble(&RunSpec {
                l,
                load: VolumeLoad::Sites(1),
                mode,
                trials,
                steps,
                seed: 43,
                streams: repro::pdes::StreamFamily::Pe,
                control: repro::coordinator::Control::Static,
            });
            let u_nat = native.tail_mean(Lane::U, 0.25);

            // cross-validation: both paths must agree within combined noise
            let err = (jax.stderr(t_end, Lane::U).powi(2)
                + native.stderr(t_end, Lane::U).powi(2))
            .sqrt();
            let dev = (u_jax - u_nat).abs();
            let pe_steps = trials as f64 * steps as f64 * l as f64;
            println!(
                "{l:>6} {:>8} {tag:>22} {u_jax:>10.4} {wa_jax:>10.3} {dev:>10.4} {:>10.2e}",
                "jax+nat",
                pe_steps / jax_secs
            );
            assert!(
                dev < (5.0 * err).max(0.02),
                "paths disagree at L={l} {tag}: jax {u_jax:.4} vs native {u_nat:.4} (err {err:.4})"
            );

            if matches!(mode, Mode::Conservative) {
                xs.push(1.0 / l as f64);
                us.push(u_jax);
            }
        }
    }

    // headline: extrapolated utilization stays finite...
    let fit = extrapolate_to_zero(&xs, &us).expect("extrapolation");
    println!(
        "\nheadline (artifact path, N_V = 1, unconstrained): u_inf = {:.4}  (paper: 0.2465)",
        fit.at_zero()
    );
    // ...and the window bounds the width on the largest ring
    let spec = JaxRunSpec {
        l: 1024,
        load: VolumeLoad::Sites(1),
        mode: Mode::Windowed { delta },
        trials: 16,
        steps,
        seed: 44,
    };
    let s = run_artifact_ensemble(&mut rt, &spec)?;
    let wa = s.mean(s.steps() - 1, Lane::Wa);
    println!(
        "headline (L = 1024, Δ = {delta}): <w_a> = {wa:.3} ≤ Δ — the measurement phase scales"
    );
    assert!(wa < delta);
    println!("\ne2e campaign OK — all layers compose and cross-validate.");
    Ok(())
}

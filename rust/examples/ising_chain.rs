//! A real application of the constrained conservative scheduler: the
//! asynchronous kinetic Ising chain (Glauber dynamics) — the class of
//! "dynamic Monte Carlo" workloads the paper's introduction motivates.
//!
//! Each PE carries one spin of a periodic J > 0 chain.  When the PDES
//! scheduler grants PE k an update (its local virtual time is a local
//! minimum, Eq. 1, and inside the Δ-window, Eq. 3), the spin attempts a
//! Glauber flip using its neighbours' states — which is *causally safe*
//! precisely because Eq. 1 guarantees both neighbours' virtual times are
//! ahead, so their states at the event's virtual time are known.
//!
//! Validation: the time-averaged energy per spin must match the exact 1-d
//! equilibrium value  e = -J tanh(J / k_B T),  independent of Δ — the
//! window changes *scheduling*, not physics.
//!
//! Run with: `cargo run --release --example ising_chain [beta]`

use repro::pdes::{Mode, VolumeLoad};
use repro::rng::Rng;

/// Asynchronous Ising chain driven by a conservative Δ-window PDES.
struct IsingPdes {
    tau: Vec<f64>,
    next_tau: Vec<f64>,
    spins: Vec<i8>,
    mode: Mode,
    beta: f64,
    rng: Rng,
}

impl IsingPdes {
    fn new(l: usize, beta: f64, mode: Mode, rng: Rng) -> Self {
        Self {
            tau: vec![0.0; l],
            next_tau: vec![0.0; l],
            spins: vec![1; l], // ordered start
            mode,
            beta,
            rng,
        }
    }

    /// One parallel step; returns the number of spin-update events.
    fn step(&mut self) -> usize {
        let l = self.tau.len();
        let edge = if self.mode.enforces_window() {
            self.mode.delta() + self.tau.iter().copied().fold(f64::INFINITY, f64::min)
        } else {
            f64::INFINITY
        };
        let mut events = 0;
        for k in 0..l {
            let tk = self.tau[k];
            let left_i = if k == 0 { l - 1 } else { k - 1 };
            let right_i = if k + 1 == l { 0 } else { k + 1 };
            let ok = tk <= self.tau[left_i].min(self.tau[right_i]) && tk <= edge;
            if ok {
                // Glauber flip attempt at virtual time tk
                let h = (self.spins[left_i] + self.spins[right_i]) as f64;
                let d_e = 2.0 * self.spins[k] as f64 * h; // J = 1
                let p_flip = 1.0 / (1.0 + (self.beta * d_e).exp());
                if self.rng.uniform() < p_flip {
                    self.spins[k] = -self.spins[k];
                }
                self.next_tau[k] = tk + self.rng.exponential();
                events += 1;
            } else {
                self.next_tau[k] = tk;
            }
        }
        std::mem::swap(&mut self.tau, &mut self.next_tau);
        events
    }

    fn energy_per_spin(&self) -> f64 {
        let l = self.spins.len();
        let mut e = 0.0;
        for k in 0..l {
            e -= (self.spins[k] * self.spins[(k + 1) % l]) as f64;
        }
        e / l as f64
    }

    fn magnetization(&self) -> f64 {
        self.spins.iter().map(|&s| s as f64).sum::<f64>() / self.spins.len() as f64
    }
}

fn main() {
    let beta: f64 = std::env::args()
        .nth(1)
        .and_then(|s| s.parse().ok())
        .unwrap_or(0.7);
    let l = 512;
    let warm = 4000;
    let measure = 16000;
    let exact = -(beta.tanh()); // e = -J tanh(beta J), J = 1

    println!("asynchronous Glauber Ising chain, L = {l}, beta = {beta}");
    println!("exact equilibrium energy/spin: {exact:.4}\n");
    println!(
        "{:>24} {:>10} {:>10} {:>10} {:>8}",
        "scheduler", "<e>", "err", "<|m|>", "u"
    );

    let _ = VolumeLoad::Sites(1); // (the chain is the N_V = 1 workload)
    for (label, mode) in [
        ("unconstrained", Mode::Conservative),
        ("Δ-window (Δ = 20)", Mode::Windowed { delta: 20.0 }),
        ("Δ-window (Δ = 5)", Mode::Windowed { delta: 5.0 }),
    ] {
        let mut sim = IsingPdes::new(l, beta, mode, Rng::for_stream(2002, 1));
        for _ in 0..warm {
            sim.step();
        }
        let (mut se, mut sm, mut su) = (0.0, 0.0, 0.0);
        for _ in 0..measure {
            let ev = sim.step();
            se += sim.energy_per_spin();
            sm += sim.magnetization().abs();
            su += ev as f64 / l as f64;
        }
        let e = se / measure as f64;
        println!(
            "{label:>24} {e:>10.4} {:>10.4} {:>10.4} {:>8.3}",
            (e - exact).abs(),
            sm / measure as f64,
            su / measure as f64
        );
    }

    println!("\nthe sampled physics is Δ-independent (scheduling ≠ dynamics), while u");
    println!("and the memory bound follow the paper's trade-off — the PDES is faithful.");
}

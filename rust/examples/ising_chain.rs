//! A real application of the constrained conservative scheduler: the
//! asynchronous kinetic Ising chain (Glauber dynamics) — the class of
//! "dynamic Monte Carlo" workloads the paper's introduction motivates.
//!
//! Since the model-payload subsystem (`pdes::model`) this example is a
//! thin driver over the production engines: the `Ising1d` payload rides
//! `BatchPdes`/`ShardedPdes` through the coordinator's model-steady fold
//! — no hand-rolled PDES loop, trial batching, lattice sharding and the
//! campaign cache all apply to the physics workload for free (see also
//! `repro ising`, the full Δ-sweep experiment).
//!
//! Validation: the time-averaged energy per spin matches the exact 1-d
//! equilibrium value e = −J·tanh(βJ) independent of Δ — the window
//! changes *scheduling*, not physics (enforced with documented
//! tolerances by `tests/ising_physics.rs`).
//!
//! Run with: `cargo run --release --example ising_chain [--quick] [beta]`

use repro::coordinator::{model_steady_topology, RunSpec, ShardStrategy};
use repro::pdes::{Ising1d, Mode, ModelSpec, Topology, VolumeLoad};

fn main() {
    let args: Vec<String> = std::env::args().skip(1).collect();
    let quick = args.iter().any(|a| a == "--quick");
    let beta: f64 = args
        .iter()
        .find(|a| !a.starts_with("--"))
        .and_then(|s| s.parse().ok())
        .unwrap_or(0.7);
    let (l, trials, warm, measure) = if quick {
        (128usize, 2u64, 500usize, 2000usize)
    } else {
        (512, 8, 2000, 8000)
    };
    let exact = Ising1d::exact_ring_energy(beta, 1.0);

    println!(
        "asynchronous Glauber Ising chain on the PDES engines: L = {l}, beta = {beta}, \
         {trials} trials, {warm}+{measure} steps"
    );
    println!("exact equilibrium energy/spin: {exact:.4}\n");
    println!(
        "{:>24} {:>10} {:>10} {:>10} {:>8}",
        "scheduler", "<e>", "err", "<|m|>", "u"
    );

    for (label, mode) in [
        ("unconstrained", Mode::Conservative),
        ("Δ-window (Δ = 20)", Mode::Windowed { delta: 20.0 }),
        ("Δ-window (Δ = 5)", Mode::Windowed { delta: 5.0 }),
    ] {
        let st = model_steady_topology(
            Topology::Ring { l },
            &RunSpec {
                l,
                load: VolumeLoad::Sites(1), // one spin per PE
                mode,
                trials,
                steps: 0,
                seed: 2002,
                streams: repro::pdes::StreamFamily::Pe,
                control: repro::coordinator::Control::Static,
            },
            &ModelSpec::Ising { beta, coupling: 1.0 },
            warm,
            measure,
            ShardStrategy::Trials,
        );
        println!(
            "{label:>24} {:>10.4} {:>10.4} {:>10.4} {:>8.3}",
            st.e,
            (st.e - exact).abs(),
            st.m_abs,
            st.u
        );
    }

    println!("\nthe sampled physics is Δ-independent (scheduling ≠ dynamics), while u");
    println!("and the memory bound follow the paper's trade-off — the PDES is faithful.");
}

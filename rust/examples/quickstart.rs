//! Quickstart: simulate a ring of 100 PEs with and without the moving
//! Δ-window constraint and print the paper's two headline observables —
//! the utilization (simulation phase) and the STH width (measurement
//! phase).  Run with: `cargo run --release --example quickstart [--quick]`

use repro::coordinator::{run_ensemble, RunSpec};
use repro::pdes::{Mode, VolumeLoad};
use repro::stats::Lane;

fn main() {
    let quick = std::env::args().any(|a| a == "--quick");
    let base = RunSpec {
        l: 100,
        load: VolumeLoad::Sites(1),
        mode: Mode::Conservative,
        trials: if quick { 8 } else { 32 },
        steps: if quick { 800 } else { 8000 },
        seed: 7,
        streams: repro::pdes::StreamFamily::Pe,
        control: repro::coordinator::Control::Static,
    };

    println!(
        "ring of {} PEs, 1 site/PE, {} trials, {} steps\n",
        base.l, base.trials, base.steps
    );

    for (label, mode) in [
        ("unconstrained (basic conservative)", Mode::Conservative),
        ("Δ-window constrained (Δ = 3)", Mode::Windowed { delta: 3.0 }),
    ] {
        let series = run_ensemble(&RunSpec { mode, ..base });
        let t_end = series.steps() - 1;
        println!("{label}:");
        println!(
            "  <u>   = {:.3}  (fraction of PEs working per step)",
            series.mean(t_end, Lane::U)
        );
        println!(
            "  <w>   = {:.3}  (RMS width of the virtual time horizon)",
            series.mean(t_end, Lane::W)
        );
        println!(
            "  <w_a> = {:.3}  (absolute spread — the memory bound per PE)",
            series.mean(t_end, Lane::Wa)
        );
        println!();
    }

    println!("note: the window bounds the width (measurement phase scales) while");
    println!("the utilization stays finite (simulation phase scales) — the paper's result.");
}

//! End-to-end benches: one per paper figure/table (DESIGN.md §4), timing
//! the full regeneration pipeline at quick scale so `cargo bench` exercises
//! every experiment path, plus the artifact-runtime bench for the JAX path.
//!
//! These are throughput/latency measurements of *our* harness, not the
//! paper's numbers; EXPERIMENTS.md records the science output separately.

use std::time::Duration;

use repro::bench::Bencher;
use repro::coordinator::{run_with_executor_bench, JaxRunSpec};
use repro::experiments::{self, Ctx};
use repro::pdes::{Mode, VolumeLoad};
use repro::runtime::PdesRuntime;

fn main() {
    let out = std::env::temp_dir().join("repro_bench_out");
    let ctx = Ctx::new(&out, true); // quick grids: benches time the pipeline
    // one warmup + one sample per figure: each regeneration is seconds-long,
    // so repeated sampling would dominate the bench budget
    let b = Bencher::new(Duration::from_millis(1), Duration::from_millis(1), 1);

    println!("# per-figure end-to-end benches (quick grids; items = 1 regeneration)");
    for name in experiments::ALL {
        b.report(&format!("figure/{name}"), 1.0, || {
            experiments::run(name, &ctx).expect(name);
        });
    }

    // artifact path: chunk execution throughput (PE-steps/s through PJRT)
    match PdesRuntime::load(std::path::Path::new("artifacts")) {
        Ok(mut rt) => {
            let exe = rt.executor("pdes_L64_B32_T32").expect("artifact");
            let info = exe.info().clone();
            let spec = JaxRunSpec {
                l: info.l,
                load: VolumeLoad::Sites(1),
                mode: Mode::Windowed { delta: 10.0 },
                trials: info.b as u64,
                steps: info.t_chunk,
                seed: 5,
            };
            let items = (info.l * info.b * info.t_chunk) as f64;
            b.report("runtime/chunk_L64_B32_T32", items, || {
                run_with_executor_bench(&exe, &spec).expect("chunk");
            });
        }
        Err(e) => println!("runtime bench skipped (no artifacts): {e}"),
    }
}

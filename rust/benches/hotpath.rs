//! Microbenchmarks of the hot paths, used by the §Perf iteration loop
//! (own harness — criterion is unavailable offline).
//!
//! Reported throughput unit: PE-steps/second (one PE-step = one update
//! attempt of one processing element).

use std::time::Duration;

use repro::bench::Bencher;
use repro::pdes::{BatchPdes, InstrumentedRing, LatticePdes, Mode, RingPdes, Topology, VolumeLoad};
use repro::rng::Rng;
use repro::stats::horizon_frame;

fn main() {
    let quick = std::env::args().any(|a| a == "--quick");
    let b = if quick {
        Bencher::quick()
    } else {
        Bencher::new(Duration::from_millis(200), Duration::from_secs(1), 7)
    };

    println!("# hotpath microbenches (items = PE-steps unless noted)");

    for (name, l, load, mode) in [
        (
            "ring_step/conservative_L1000_NV1",
            1000usize,
            VolumeLoad::Sites(1),
            Mode::Conservative,
        ),
        (
            "ring_step/conservative_L1000_NV100",
            1000,
            VolumeLoad::Sites(100),
            Mode::Conservative,
        ),
        (
            "ring_step/windowed10_L1000_NV1",
            1000,
            VolumeLoad::Sites(1),
            Mode::Windowed { delta: 10.0 },
        ),
        (
            "ring_step/rd_L1000",
            1000,
            VolumeLoad::Infinite,
            Mode::Rd,
        ),
    ] {
        let mut sim = RingPdes::new(l, load, mode, Rng::for_stream(1, 0));
        for _ in 0..500 {
            sim.step(); // reach steady state so branch mix is realistic
        }
        b.report(name, l as f64, || {
            std::hint::black_box(sim.step());
        });
    }

    // ring vs batch: the acceptance bar is batched per-step-per-PE
    // throughput at parity or better than the serial ring for B >= 8
    // (items = B * L PE-steps per batched step)
    for rows in [1usize, 8, 32] {
        let mut sim = BatchPdes::with_streams(
            Topology::Ring { l: 1000 },
            VolumeLoad::Sites(1),
            Mode::Conservative,
            rows,
            1,
            0,
        );
        for _ in 0..500 {
            sim.step();
        }
        b.report(
            &format!("batch_step/ring_L1000_NV1_B{rows}"),
            (1000 * rows) as f64,
            || {
                sim.step();
                std::hint::black_box(sim.counts()[0]);
            },
        );
    }

    // per-topology step throughput at B = 8 (items = B * L PE-steps)
    for (name, topo) in [
        ("ring_L1024", Topology::Ring { l: 1024 }),
        ("kring2_L1024", Topology::KRing { l: 1024, k: 2 }),
        ("smallworld_L1024", Topology::SmallWorld { l: 1024, extra: 256, seed: 9 }),
        ("square32", Topology::Square { side: 32 }),
        ("cubic10", Topology::Cubic { side: 10 }),
    ] {
        let mut sim = BatchPdes::with_streams(
            topo,
            VolumeLoad::Sites(1),
            Mode::Windowed { delta: 10.0 },
            8,
            2,
            0,
        );
        for _ in 0..300 {
            sim.step();
        }
        b.report(
            &format!("batch_step/{name}_B8"),
            (topo.len() * 8) as f64,
            || {
                sim.step();
                std::hint::black_box(sim.counts()[0]);
            },
        );
    }

    // instrumented ring (mean-field counters) — the overhead must be known
    let mut inst = InstrumentedRing::new(
        1000,
        VolumeLoad::Sites(10),
        Mode::Windowed { delta: 10.0 },
        Rng::for_stream(2, 0),
    );
    for _ in 0..500 {
        inst.step();
    }
    b.report("ring_step/instrumented_L1000_NV10_d10", 1000.0, || {
        std::hint::black_box(inst.step());
    });

    // 2-d lattice
    let mut lat = LatticePdes::new(
        Topology::Square { side: 32 },
        Mode::Conservative,
        Rng::for_stream(3, 0),
    );
    for _ in 0..500 {
        lat.step();
    }
    b.report("lattice_step/square32_conservative", 1024.0, || {
        std::hint::black_box(lat.step());
    });

    // statistics frame (per-PE cost of the measurement pipeline)
    let tau: Vec<f64> = (0..1000).map(|i| ((i * 37) % 101) as f64 * 0.1).collect();
    b.report("stats/horizon_frame_L1000", 1000.0, || {
        std::hint::black_box(horizon_frame(&tau, 250));
    });

    // rng draws (items = draws)
    let mut rng = Rng::for_stream(4, 0);
    b.report("rng/uniform", 1.0, || {
        std::hint::black_box(rng.uniform());
    });
    b.report("rng/exponential", 1.0, || {
        std::hint::black_box(rng.exponential());
    });
}

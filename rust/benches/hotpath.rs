//! Microbenchmarks of the hot paths, used by the §Perf iteration loop and
//! the CI regression gate (own harness — criterion is unavailable offline).
//!
//! Reported throughput unit: PE-steps/second (one PE-step = one update
//! attempt of one processing element).
//!
//! Flags:
//! * `--quick`            CI-friendly budgets;
//! * `--json <path>`      write the machine-readable report (the schema of
//!                        the committed `BENCH_2.json` baseline);
//! * `--compare <path>`   compare against a baseline JSON and exit
//!                        non-zero on a throughput regression beyond
//!                        `BENCH_TOLERANCE` (default 0.30 = 30 %).
//!
//! The canonical regression-gate grid is `batch_step/ring_L{l}_NV1_B{b}`
//! for B ∈ {1, 8, 64} × L ∈ {1000, 10000}, windowed at Δ = 10 (the
//! paper's measurement-phase configuration), plus the fused-vs-split
//! measurement pairs `measure_fused/...` / `measure_split/...` over the
//! same grid — the fused path must win at every (B, L) — plus (since the
//! declarative-campaign PR) the scheduler-throughput grid
//! `campaign/points_W{1,2,4}` (items = sweep points through `run_plan`),
//! plus (since the decision-kernel PR) the isolated decide-pass grid
//! `decide_kernel/{scalar,simd}_L{1e4,1e5}_B{1,4,8}` whose acceptance
//! bar is simd >= 1.8x scalar at L = 1e5, B = 8 under AVX2.

use std::path::PathBuf;
use std::time::Duration;

use repro::bench::{compare_against_baseline, BenchReport, Bencher};
use repro::coordinator::{run_plan, CampaignOpts, RunSpec, SweepPlan, SweepPoint};
use repro::pdes::{
    kernel_provenance, simd_supported, ActiveKernel, BatchPdes, InstrumentedRing, LatticePdes,
    Mode, ModelSpec, RingPdes, ShardedPdes, StreamFamily, Topology, VolumeLoad,
};
use repro::rng::Rng;
use repro::stats::{horizon_frame, horizon_frame_fused, StepStats};

/// Value of `--flag <value>` in argv, if present.
fn flag_value(args: &[String], flag: &str) -> Option<String> {
    args.iter()
        .position(|a| a == flag)
        .and_then(|i| args.get(i + 1))
        .cloned()
}

/// Resolve a `--json`/`--compare` path: absolute paths pass through;
/// relative ones resolve against the *workspace root* (the committed
/// `BENCH_2.json` lives there, while `cargo bench` sets the binary's CWD
/// to the package dir `rust/`).
fn resolve(path: &str) -> PathBuf {
    let p = PathBuf::from(path);
    if p.is_absolute() {
        p
    } else {
        // rust/ -> workspace root
        PathBuf::from(env!("CARGO_MANIFEST_DIR"))
            .parent()
            .expect("package dir has a parent")
            .join(p)
    }
}

fn main() {
    let args: Vec<String> = std::env::args().collect();
    let quick = args.iter().any(|a| a == "--quick");
    let json_path = flag_value(&args, "--json");
    let compare_path = flag_value(&args, "--compare");
    let b = if quick {
        Bencher::quick()
    } else {
        Bencher::new(Duration::from_millis(200), Duration::from_secs(1), 7)
    };
    // Provenance records the detected ISA and the kernel the decide pass
    // dispatches to on THIS machine (ISSUE 9) — the numbers in the JSON
    // are meaningless without it.  kernel_provenance() emits plain
    // `key=value` pairs (no quotes/backslashes), which BenchReport
    // requires of its provenance string.
    let provenance = format!(
        "{}; {}",
        if quick { "quick run" } else { "full run" },
        kernel_provenance(),
    );
    let mut report = BenchReport::new("hotpath", &provenance);

    println!("# hotpath microbenches (items = PE-steps unless noted)");

    for (name, l, load, mode) in [
        (
            "ring_step/conservative_L1000_NV1",
            1000usize,
            VolumeLoad::Sites(1),
            Mode::Conservative,
        ),
        (
            "ring_step/conservative_L1000_NV100",
            1000,
            VolumeLoad::Sites(100),
            Mode::Conservative,
        ),
        (
            "ring_step/windowed10_L1000_NV1",
            1000,
            VolumeLoad::Sites(1),
            Mode::Windowed { delta: 10.0 },
        ),
        (
            "ring_step/rd_L1000",
            1000,
            VolumeLoad::Infinite,
            Mode::Rd,
        ),
    ] {
        let mut sim = RingPdes::new(l, load, mode, Rng::for_stream(1, 0));
        for _ in 0..500 {
            sim.step(); // reach steady state so branch mix is realistic
        }
        let m = b.report(name, l as f64, || {
            std::hint::black_box(sim.step());
        });
        report.push(name, l as f64, m);
    }

    // The regression-gate grid: windowed Δ = 10 ring batches (the paper's
    // measurement-phase configuration) over B × L.  The acceptance case
    // of the fused-hot-path PR is batch_step/ring_L1000_NV1_B8.
    for &l in &[1000usize, 10_000] {
        for &rows in &[1usize, 8, 64] {
            let mut sim = BatchPdes::with_streams(
                Topology::Ring { l },
                VolumeLoad::Sites(1),
                Mode::Windowed { delta: 10.0 },
                rows,
                1,
                0,
            );
            let warm = if l >= 10_000 { 150 } else { 500 };
            for _ in 0..warm {
                sim.step();
            }
            let name = format!("batch_step/ring_L{l}_NV1_B{rows}");
            let items = (l * rows) as f64;
            let m = b.report(&name, items, || {
                sim.step();
                std::hint::black_box(sim.counts()[0]);
            });
            report.push(&name, items, m);
        }
    }

    // Decision-kernel grid (ISSUE 9): the decide pass in isolation —
    // `decide_only()` runs exactly the lane-blocked kernel dispatch that
    // `step_masked` uses (fused Eq. 3 window compare included) and
    // nothing else, so scalar-vs-SIMD ratios here are pure kernel
    // speedups, not diluted by the RNG-bound update pass.  The
    // acceptance bar is >= 1.8x at L = 1e5, B = 8 with AVX2 (summary
    // below).  Without AVX2 the simd cases are skipped — the committed
    // BENCH_2.json provenance documents the arming procedure.
    for &l in &[10_000usize, 100_000] {
        for &rows in &[1usize, 4, 8] {
            let mut sim = BatchPdes::with_streams(
                Topology::Ring { l },
                VolumeLoad::Sites(1),
                Mode::Windowed { delta: 10.0 },
                rows,
                6,
                0,
            );
            let warm = if l >= 100_000 { 30 } else { 150 };
            for _ in 0..warm {
                sim.step();
            }
            let items = (l * rows) as f64;
            let mut kernels = vec![("scalar", ActiveKernel::Scalar)];
            if simd_supported() {
                kernels.push(("simd", ActiveKernel::SimdAvx2));
            }
            for (tag, kernel) in kernels {
                sim.set_decide_kernel(kernel);
                let name = format!("decide_kernel/{tag}_L{l}_B{rows}");
                let m = b.report(&name, items, || {
                    std::hint::black_box(sim.decide_only());
                });
                report.push(&name, items, m);
            }
        }
    }

    // Model-payload family (the pluggable-payload PR): `none` is the
    // engine with ModelSpec::None applied — which attaches NOTHING, so
    // it must ride the PR 2 fused path and stay within noise of the
    // matching batch_step/ring_L{l}_NV1_B8 case (the summary below
    // prints the ratio; the JSON gate pins it against the baseline).
    // `ising` adds one Glauber flip (one uniform + one exp() call) per
    // event — the honest cost of a real dynamic Monte Carlo payload.
    for &l in &[1000usize, 10_000] {
        for model in [ModelSpec::None, ModelSpec::Ising { beta: 0.7, coupling: 1.0 }] {
            let rows = 8usize;
            let mut sim = BatchPdes::with_streams(
                Topology::Ring { l },
                VolumeLoad::Sites(1),
                Mode::Windowed { delta: 10.0 },
                rows,
                3,
                0,
            );
            let models = model.build_rows(l, rows);
            if !models.is_empty() {
                sim.attach_models(models);
            }
            let warm = if l >= 10_000 { 150 } else { 500 };
            for _ in 0..warm {
                sim.step();
            }
            let name = format!("model_step/{}_L{l}", model.tag());
            let items = (l * rows) as f64;
            let m = b.report(&name, items, || {
                sim.step();
                std::hint::black_box(sim.counts()[0]);
            });
            report.push(&name, items, m);
        }
    }

    // Sharded scaling grid (PR 3; RowV1 family for baseline continuity):
    // the domain-decomposed engine over workers x L, windowed Δ = 10 ring
    // at N_V = 1, B = 4 rows (so phase B has row-level parallelism too).
    // W1 is the sharded engine's overhead floor against batch_step; the
    // W{2,4,8} columns are the scaling claim.  Since the persistent-pool
    // PR the per-step cost is a park/wake handshake, not thread spawns —
    // under RowV1 phase B still serializes within each row, so scaling
    // here rides phase A + row parallelism only.
    for &l in &[1_000usize, 10_000, 100_000] {
        for &workers in &[1usize, 2, 4, 8] {
            let mut sim = ShardedPdes::with_streams(
                Topology::Ring { l },
                VolumeLoad::Sites(1),
                Mode::Windowed { delta: 10.0 },
                4,
                5,
                0,
                workers,
            );
            let warm = if l >= 100_000 {
                30
            } else if l >= 10_000 {
                150
            } else {
                500
            };
            for _ in 0..warm {
                sim.step();
            }
            let name = format!("sharded_step/ring_L{l}_NV1_B4_W{workers}");
            let items = (l * 4) as f64;
            let m = b.report(&name, items, || {
                sim.step();
                std::hint::black_box(sim.counts()[0]);
            });
            report.push(&name, items, m);
        }
    }

    // Per-PE-family scaling grid (persistent-pool PR): B = 1, so every
    // drop of parallelism must come from *inside* the row — impossible
    // under RowV1, the whole point of the per-PE streams.  The acceptance
    // bar lives on L = 1e4: W4 must reach >= 2x W1 (`pe scaling` summary
    // below).  Zero thread spawns per step (pool parked between steps).
    for &l in &[10_000usize, 100_000] {
        for &workers in &[1usize, 2, 4, 8] {
            let mut sim = ShardedPdes::with_streams_family(
                Topology::Ring { l },
                VolumeLoad::Sites(1),
                Mode::Windowed { delta: 10.0 },
                1,
                5,
                0,
                workers,
                StreamFamily::Pe,
            );
            let warm = if l >= 100_000 { 30 } else { 150 };
            for _ in 0..warm {
                sim.step();
            }
            let name = format!("sharded_step_pe/ring_L{l}_NV1_B1_W{workers}");
            let items = l as f64;
            let m = b.report(&name, items, || {
                sim.step();
                std::hint::black_box(sim.counts()[0]);
            });
            report.push(&name, items, m);
        }
    }

    // Fused measurement (step pass emits StepStats; one deviation pass
    // per row) vs the split legacy shape (step, then two-pass
    // horizon_frame per row).  Same sim drives both of a pair so the
    // branch mix matches; the fused path must win at every (B, L).
    for &l in &[1000usize, 10_000] {
        for &rows in &[1usize, 8, 64] {
            let mut sim = BatchPdes::with_streams(
                Topology::Ring { l },
                VolumeLoad::Sites(1),
                Mode::Windowed { delta: 10.0 },
                rows,
                2,
                0,
            );
            let warm = if l >= 10_000 { 150 } else { 500 };
            for _ in 0..warm {
                sim.step();
            }
            let items = (l * rows) as f64;

            let name = format!("measure_fused/ring_L{l}_B{rows}");
            let m = b.report(&name, items, || {
                sim.step();
                for row in 0..rows {
                    std::hint::black_box(horizon_frame_fused(
                        sim.tau_row(row),
                        &sim.step_stats_row(row),
                    ));
                }
            });
            report.push(&name, items, m);

            let name = format!("measure_split/ring_L{l}_B{rows}");
            let m = b.report(&name, items, || {
                sim.step();
                for row in 0..rows {
                    std::hint::black_box(horizon_frame(
                        sim.tau_row(row),
                        sim.counts()[row] as usize,
                    ));
                }
            });
            report.push(&name, items, m);
        }
    }

    // per-topology step throughput at B = 8 (items = B * L PE-steps)
    for (name, topo) in [
        ("ring_L1024", Topology::Ring { l: 1024 }),
        ("kring2_L1024", Topology::KRing { l: 1024, k: 2 }),
        ("smallworld_L1024", Topology::SmallWorld { l: 1024, extra: 256, seed: 9 }),
        ("square32", Topology::Square { side: 32 }),
        ("cubic10", Topology::Cubic { side: 10 }),
    ] {
        let mut sim = BatchPdes::with_streams(
            topo,
            VolumeLoad::Sites(1),
            Mode::Windowed { delta: 10.0 },
            8,
            2,
            0,
        );
        for _ in 0..300 {
            sim.step();
        }
        let full = format!("batch_step/{name}_B8");
        let items = (topo.len() * 8) as f64;
        let m = b.report(&full, items, || {
            sim.step();
            std::hint::black_box(sim.counts()[0]);
        });
        report.push(&full, items, m);
    }

    // instrumented ring (mean-field counters) — the overhead must be known
    let mut inst = InstrumentedRing::new(
        1000,
        VolumeLoad::Sites(10),
        Mode::Windowed { delta: 10.0 },
        Rng::for_stream(2, 0),
    );
    for _ in 0..500 {
        inst.step();
    }
    let m = b.report("ring_step/instrumented_L1000_NV10_d10", 1000.0, || {
        std::hint::black_box(inst.step());
    });
    report.push("ring_step/instrumented_L1000_NV10_d10", 1000.0, m);

    // 2-d lattice
    let mut lat = LatticePdes::new(
        Topology::Square { side: 32 },
        Mode::Conservative,
        Rng::for_stream(3, 0),
    );
    for _ in 0..500 {
        lat.step();
    }
    let m = b.report("lattice_step/square32_conservative", 1024.0, || {
        std::hint::black_box(lat.step());
    });
    report.push("lattice_step/square32_conservative", 1024.0, m);

    // statistics frames (per-PE cost of the measurement pipeline, outside
    // the stepper): classic two-pass vs fused one-pass given a pre-pass
    let tau: Vec<f64> = (0..1000).map(|i| ((i * 37) % 101) as f64 * 0.1).collect();
    let m = b.report("stats/horizon_frame_L1000", 1000.0, || {
        std::hint::black_box(horizon_frame(&tau, 250));
    });
    report.push("stats/horizon_frame_L1000", 1000.0, m);
    let pre = StepStats::measure(&tau, 250);
    let m = b.report("stats/horizon_frame_fused_L1000", 1000.0, || {
        std::hint::black_box(horizon_frame_fused(&tau, &pre));
    });
    report.push("stats/horizon_frame_fused_L1000", 1000.0, m);

    // campaign-scheduler throughput (items = sweep points): a small
    // steady plan dispatched through run_plan at point-level workers
    // W ∈ {1, 2, 4}.  Measures the declarative layer's overhead and its
    // point-level scaling; per-point results are bitwise identical across
    // W (the scheduler contract), so only wall-clock moves.
    {
        let mut plan = SweepPlan::new("bench", "campaign throughput plan");
        for i in 0..8usize {
            let l = 32 + 4 * i;
            plan.push(SweepPoint::steady(
                format!("L{l}"),
                Topology::Ring { l },
                RunSpec {
                    l,
                    load: VolumeLoad::Sites(1),
                    mode: Mode::Windowed { delta: 5.0 },
                    trials: 4,
                    steps: 0,
                    seed: 11,
                    streams: StreamFamily::RowV1,
                    control: repro::coordinator::Control::Static,
                },
                60,
                60,
            ));
        }
        let items = plan.len() as f64;
        for &workers in &[1usize, 2, 4] {
            let opts = CampaignOpts {
                workers,
                quiet: true,
                ..Default::default()
            };
            let name = format!("campaign/points_W{workers}");
            let m = b.report(&name, items, || {
                let (results, _) = run_plan(&plan, &opts).expect("bench plan");
                std::hint::black_box(results.len());
            });
            report.push(&name, items, m);
        }
    }

    // rng draws (items = draws)
    let mut rng = Rng::for_stream(4, 0);
    let m = b.report("rng/uniform", 1.0, || {
        std::hint::black_box(rng.uniform());
    });
    report.push("rng/uniform", 1.0, m);
    let m = b.report("rng/exponential", 1.0, || {
        std::hint::black_box(rng.exponential());
    });
    report.push("rng/exponential", 1.0, m);

    // campaign scaling summary: points/sec speedup over W1
    if let Some(base) = report.throughput_of("campaign/points_W1") {
        for &workers in &[2usize, 4] {
            if let Some(t) = report.throughput_of(&format!("campaign/points_W{workers}")) {
                println!("# campaign scaling W{workers}: x{:.2} vs W1", t / base);
            }
        }
    }

    // sharded scaling summary: speedup of W{2,4,8} over W1 per L
    for &l in &[1_000usize, 10_000, 100_000] {
        let base = report.throughput_of(&format!("sharded_step/ring_L{l}_NV1_B4_W1"));
        for &workers in &[2usize, 4, 8] {
            let t = report.throughput_of(&format!("sharded_step/ring_L{l}_NV1_B4_W{workers}"));
            if let (Some(b1), Some(tw)) = (base, t) {
                println!("# sharded scaling L{l} W{workers}: x{:.2} vs W1", tw / b1);
            }
        }
    }

    // per-PE-family scaling summary: the acceptance bar is W4 >= 2x W1
    // at B = 1, L = 1e4 (intra-row parallelism that RowV1 cannot reach)
    for &l in &[10_000usize, 100_000] {
        let base = report.throughput_of(&format!("sharded_step_pe/ring_L{l}_NV1_B1_W1"));
        for &workers in &[2usize, 4, 8] {
            let t = report.throughput_of(&format!("sharded_step_pe/ring_L{l}_NV1_B1_W{workers}"));
            if let (Some(b1), Some(tw)) = (base, t) {
                let note = if l == 10_000 && workers == 4 {
                    if tw / b1 >= 2.0 {
                        " (acceptance: >= 2x — PASS)"
                    } else {
                        " (acceptance: >= 2x — FAIL)"
                    }
                } else {
                    ""
                };
                println!("# pe scaling L{l} W{workers}: x{:.2} vs W1{note}", tw / b1);
            }
        }
    }

    // decide-kernel summary: SIMD speedup over scalar on the isolated
    // decide pass; the tentpole bar is >= 1.8x at L = 1e5, B = 8
    if simd_supported() {
        for &l in &[10_000usize, 100_000] {
            for &rows in &[1usize, 4, 8] {
                let scalar = report.throughput_of(&format!("decide_kernel/scalar_L{l}_B{rows}"));
                let simd = report.throughput_of(&format!("decide_kernel/simd_L{l}_B{rows}"));
                if let (Some(s), Some(v)) = (scalar, simd) {
                    let note = if l == 100_000 && rows == 8 {
                        if v / s >= 1.8 {
                            " (acceptance: >= 1.8x — PASS)"
                        } else {
                            " (acceptance: >= 1.8x — FAIL)"
                        }
                    } else {
                        ""
                    };
                    println!("# decide kernel L{l} B{rows}: simd x{:.2} vs scalar{note}", v / s);
                }
            }
        }
    } else {
        println!("# decide kernel: AVX2 unavailable on this machine — simd cases skipped");
    }

    // model-payload summary: NoModel must be free (ratio ≈ 1 vs the
    // fused batch_step at the same shape — the payload PR's bench gate),
    // and the Ising cost is reported for the record
    for &l in &[1000usize, 10_000] {
        let base = report.throughput_of(&format!("batch_step/ring_L{l}_NV1_B8"));
        let none = report.throughput_of(&format!("model_step/none_L{l}"));
        let ising = report.throughput_of(&format!("model_step/ising_L{l}"));
        if let (Some(b0), Some(n)) = (base, none) {
            println!(
                "# model none overhead L{l}: x{:.3} vs batch_step {}",
                n / b0,
                if n / b0 > 0.85 {
                    "(within noise — NoModel is free)"
                } else {
                    "(SLOWER THAN FUSED PATH — investigate)"
                }
            );
        }
        if let (Some(n), Some(i)) = (none, ising) {
            println!("# model ising cost L{l}: x{:.2} of payload-free throughput", i / n);
        }
    }

    // fused-beats-split summary (the PR's acceptance bar at every (B, L))
    for &l in &[1000usize, 10_000] {
        for &rows in &[1usize, 8, 64] {
            let fused = report.throughput_of(&format!("measure_fused/ring_L{l}_B{rows}"));
            let split = report.throughput_of(&format!("measure_split/ring_L{l}_B{rows}"));
            if let (Some(f), Some(s)) = (fused, split) {
                println!(
                    "# measure fused/split L{l} B{rows}: x{:.2} {}",
                    f / s,
                    if f >= s { "(fused wins)" } else { "(SPLIT WINS — investigate)" }
                );
            }
        }
    }

    // write the artifact first so CI uploads it even when the gate fails
    if let Some(path) = json_path {
        let path = resolve(&path);
        report.write_json(&path).expect("write bench JSON");
        println!("# wrote {}", path.display());
    }
    if let Some(path) = compare_path {
        let path = resolve(&path);
        let tolerance = std::env::var("BENCH_TOLERANCE")
            .ok()
            .and_then(|s| s.parse::<f64>().ok())
            .unwrap_or(0.30);
        let baseline = std::fs::read_to_string(&path)
            .unwrap_or_else(|e| panic!("cannot read baseline {}: {e}", path.display()));
        match compare_against_baseline(&baseline, &report, tolerance) {
            Ok(table) => println!("{table}"),
            Err(table) => {
                eprintln!("{table}");
                std::process::exit(1);
            }
        }
    }
}
